"""Metrics registry: counters, gauges, EWMA gauges, streaming quantiles.

One :class:`MetricsRegistry` per subsystem (each :class:`ServingEngine`
and :class:`FleetController` owns one) replaces the scattered ad-hoc
stat fields that used to live on them — ``ServeStats`` counters,
``step_time_ewma_s``, the fleet's wake/violation/energy tallies — so
every runtime signal has one canonical home and the legacy public
attributes become *views* over it.

Design constraints, in order:

* **Bit-identical legacy behavior.**  :class:`EwmaGauge` computes
  ``(1-α)·prev + α·x`` with exactly the float operations the old inline
  EWMA used, so the fleet's tick-envelope arithmetic (which consumes
  ``step_time_ewma_s``) cannot drift by an ulp.
* **Hot-path cheap.**  Counters are a bare attribute add; histograms
  use the P² streaming-quantile estimator (five markers per tracked
  quantile, O(1) per observation, no sample buffer growth) so decode
  ticks never pay for sorting or unbounded memory.
* **No global state.**  Registries are plain objects; nothing here
  touches module-level singletons, so two engines never share a
  counter by accident.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple, Union


class Counter:
    """A monotonically *intended* counter (plain assignable ``value`` so
    legacy ``stats.steps += 1`` view-properties can write through)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (``None`` until first set)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = v


class EwmaGauge:
    """Exponentially weighted moving average of a stream.

    ``update`` computes ``(1-α)·prev + α·x`` — the literal expression
    the serving engine's inline ``_step_ewma`` used — so replacing that
    private field with this gauge is bit-identical, which the fleet's
    next-wake arithmetic depends on."""

    __slots__ = ("name", "alpha", "value")

    def __init__(self, name: str, alpha: float = 0.2):
        self.name = name
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = (x if self.value is None
                      else (1.0 - self.alpha) * self.value + self.alpha * x)
        return self.value


class _P2:
    """P² streaming estimator for one quantile (Jain & Chlamtac 1985):
    five markers whose heights approximate the quantile without storing
    observations.  Exact below five samples."""

    __slots__ = ("q", "n", "heights", "positions", "desired", "incr")

    def __init__(self, q: float):
        self.q = q
        self.n: List[float] = []          # first five samples, sorted lazily
        self.heights: List[float] = []
        self.positions = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.desired = [1.0, 1.0 + 2 * q, 1.0 + 4 * q, 3.0 + 2 * q, 5.0]
        self.incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def observe(self, x: float) -> None:
        if len(self.heights) < 5:
            self.n.append(x)
            if len(self.n) == 5:
                self.n.sort()
                self.heights = list(self.n)
            return
        h = self.heights
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self.positions[i] += 1.0
        for i in range(5):
            self.desired[i] += self.incr[i]
        for i in (1, 2, 3):
            d = self.desired[i] - self.positions[i]
            pos, prev, nxt = (self.positions[i], self.positions[i - 1],
                              self.positions[i + 1])
            if (d >= 1.0 and nxt - pos > 1.0) or \
                    (d <= -1.0 and prev - pos < -1.0):
                d = 1.0 if d > 0 else -1.0
                # parabolic interpolation, falling back to linear
                hp = h[i] + d / (nxt - prev) * (
                    (pos - prev + d) * (h[i + 1] - h[i]) / (nxt - pos)
                    + (nxt - pos - d) * (h[i] - h[i - 1]) / (pos - prev))
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:
                    j = i + (1 if d > 0 else -1)
                    h[i] += d * (h[j] - h[i]) / (self.positions[j] - pos)
                self.positions[i] += d

    def estimate(self) -> Optional[float]:
        if self.heights:
            return self.heights[2]
        if not self.n:
            return None
        s = sorted(self.n)
        idx = min(len(s) - 1, max(0, round(self.q * (len(s) - 1))))
        return s[int(idx)]

    # ------------------------------------------------- (de)serialization --
    def state(self) -> Dict[str, object]:
        """The full marker state — restoring it resumes the estimator
        exactly (continued observations are bit-identical)."""
        return {"q": self.q, "n": list(self.n),
                "heights": list(self.heights),
                "positions": list(self.positions),
                "desired": list(self.desired), "incr": list(self.incr)}

    @classmethod
    def from_state(cls, d: Dict[str, object]) -> "_P2":
        est = cls(float(d["q"]))
        est.n = list(d["n"])
        est.heights = list(d["heights"])
        est.positions = list(d["positions"])
        est.desired = list(d["desired"])
        est.incr = list(d["incr"])
        return est


class Histogram:
    """Streaming distribution summary: count/sum/min/max plus a P²
    estimator per tracked quantile.  O(#quantiles) per observation,
    O(1) memory — safe on the decode hot path."""

    __slots__ = ("name", "count", "sum", "min", "max", "_est")

    DEFAULT_QUANTILES = (0.5, 0.95, 0.99, 0.999)

    def __init__(self, name: str,
                 quantiles: Iterable[float] = DEFAULT_QUANTILES):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._est: Dict[float, _P2] = {q: _P2(q) for q in quantiles}

    def observe(self, x: float) -> None:
        self.count += 1
        self.sum += x
        if self.min is None or x < self.min:
            self.min = x
        if self.max is None or x > self.max:
            self.max = x
        for est in self._est.values():
            est.observe(x)

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def quantile(self, q: float) -> Optional[float]:
        est = self._est.get(q)
        if est is None:
            raise KeyError(f"quantile {q} not tracked by {self.name!r}; "
                           f"tracked: {sorted(self._est)}")
        return est.estimate()

    def snapshot(self, state: bool = True) -> Dict[str, object]:
        """Serializable summary.  With ``state=True`` (default) the dict
        also carries the raw P² marker state under ``"p2"``, so
        :meth:`from_snapshot` reconstructs an estimator that continues
        bit-identically — the one representation SLO burn windows,
        flight dumps, ``BENCH_*.json`` artifacts and ``check_perf.py``
        baselines share.  ``state=False`` gives the lean summary the
        registry embeds in bench artifacts."""
        out: Dict[str, object] = {
            "count": self.count, "sum": self.sum,
            "mean": self.mean, "min": self.min, "max": self.max}
        for q, est in sorted(self._est.items()):
            out[f"p{q * 100:g}"] = est.estimate()
        if state:
            out["name"] = self.name
            out["p2"] = [est.state() for _, est in sorted(self._est.items())]
        return out

    @classmethod
    def from_snapshot(cls, snap: Dict[str, object]) -> "Histogram":
        """Rebuild a histogram from a stateful :meth:`snapshot` dict.
        The restored estimator's quantile reads — and all subsequent
        ``observe`` arithmetic — are bit-identical to the original's."""
        p2 = snap.get("p2")
        if p2 is None:
            raise ValueError("snapshot carries no P² state "
                             "(was it taken with state=False?)")
        h = cls(str(snap.get("name", "restored")),
                quantiles=tuple(float(d["q"]) for d in p2))
        h.count = int(snap["count"])
        h.sum = float(snap["sum"])
        h.min = snap["min"]
        h.max = snap["max"]
        h._est = {float(d["q"]): _P2.from_state(d) for d in p2}
        return h


_Metric = Union[Counter, Gauge, EwmaGauge, Histogram]


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Names are dotted strings (``engine.steps``,
    ``engine.step_time_s.ewma``); re-requesting a name returns the same
    object, and requesting it as a *different* kind raises — a metric
    name means one thing."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, name: str, kind, factory) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = factory()
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def ewma(self, name: str, alpha: float = 0.2) -> EwmaGauge:
        return self._get(name, EwmaGauge, lambda: EwmaGauge(name, alpha))

    def histogram(self, name: str,
                  quantiles: Tuple[float, ...] = Histogram.DEFAULT_QUANTILES
                  ) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, quantiles))

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        """Flat name → value view (histograms expand to their summary
        dict, sans marker state) — what benchmarks serialize next to
        their own numbers."""
        out: Dict[str, object] = {}
        for name, m in sorted(self._metrics.items()):
            out[name] = (m.snapshot(state=False) if isinstance(m, Histogram)
                         else m.value)
        return out
