"""Critical-path latency attribution over the trace timeline.

"Which level made this request slow?"  The recorder already carries
every lifecycle edge a request crosses — ``req.queued``,
``engine.prefill`` begins, ``req.first_token``/``req.decode`` instants,
``req.freeze``/``req.thaw``, ``engine.oom`` backoffs, the terminal
``req.slot`` end — so end-to-end latency decomposes *on the timeline
itself* into named components, one per cross-level cost:

===============  ==========  =================================================
component        layer       interval it covers
===============  ==========  =================================================
``queue_wait``   request     ``req.queued`` → admission (prefill begin /
                             prefix hit), minus any OOM-backoff suffix
``retry_backoff`` engine     the part of a queue wait after an ``engine.oom``
                             on the same engine (admission hold-off)
``prefill``      engine      prefill begin → ``req.first_token``
``decode``       engine      token-to-token gaps while resident in a slot
``migration``    fleet       ``req.freeze`` → same-engine ``req.thaw`` (or
                             fallback re-prefill begin): swap/preempt/requeue
``offload_link`` placement   ``req.freeze`` → *cross-engine* ``req.thaw`` —
                             the frozen blob crossing a link to a peer
===============  ==========  =================================================

**Arithmetic contract.**  Components sum *bit-equal* to the span-derived
end-to-end latency.  Float addition is not associative, so summing float
segment durations cannot reproduce ``t_end - t_begin`` exactly; instead
every timestamp is quantized once to integer nanoseconds and all
interval arithmetic is done in ``int``.  Each inter-milestone gap is
assigned to exactly one component (a split gap contributes
``(cut-lo) + (hi-cut) == hi-lo``), so the telescoping sum is exact —
``sum(components_ns.values()) == end_to_end_ns`` always, and
:func:`attribute_fleet` rollup totals equal the per-request sums for the
same reason.  This mirrors ``faults/report.py``: derived purely from
``TraceRecorder.events``, no side channel.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .recorder import BEGIN, END, INSTANT

NS_PER_S = 1_000_000_000

COMPONENTS = ("queue_wait", "retry_backoff", "prefill", "decode",
              "migration", "offload_link")

# which of the four cross-level layers each component's cost lives on
COMPONENT_LAYER = {
    "queue_wait": "request",
    "retry_backoff": "engine",
    "prefill": "engine",
    "decode": "engine",
    "migration": "fleet",
    "offload_link": "placement",
}


def _ns(t_s: float) -> int:
    return round(t_s * NS_PER_S)


@dataclass(frozen=True)
class RequestAttribution:
    """One request's latency decomposition.  ``pid`` is the origin
    engine (where it was first queued); ``complete`` is False for
    requests still in flight when the trace ended — their components
    cover queued → last observed milestone instead."""
    rid: int
    pid: str
    complete: bool
    begin_ns: int
    end_ns: int
    components_ns: Dict[str, int]

    @property
    def end_to_end_ns(self) -> int:
        return self.end_ns - self.begin_ns

    @property
    def end_to_end_s(self) -> float:
        return self.end_to_end_ns / NS_PER_S

    def component_s(self, name: str) -> float:
        return self.components_ns[name] / NS_PER_S

    def dominant(self) -> str:
        """The component carrying the most latency (ties resolve in
        canonical ``COMPONENTS`` order)."""
        return max(COMPONENTS, key=lambda c: (self.components_ns[c],
                                              -COMPONENTS.index(c)))

    def to_dict(self) -> Dict:
        return {"rid": self.rid, "pid": self.pid, "complete": self.complete,
                "end_to_end_s": self.end_to_end_s,
                "components_s": {c: self.component_s(c)
                                 for c in COMPONENTS},
                "dominant": self.dominant()}


# ------------------------------------------------- milestone extraction ----
_TERMINAL_REASONS = ("finished", "done_at_prefill")


def _milestones(evts: Sequence) -> Tuple[Dict[int, List[Tuple[int, str, str]]],
                                         Dict[str, List[int]]]:
    """One pass over the event list: per-rid ordered milestones
    ``(t_ns, kind, pid)`` plus per-engine ``engine.oom`` instants (used
    to split queue waits into wait vs. backoff)."""
    per: Dict[int, List[Tuple[int, str, str]]] = {}
    ooms: Dict[str, List[int]] = {}
    for e in evts:
        a = e.args or {}
        name, ph = e.name, e.ph
        if name == "req.queued" and ph == INSTANT:
            per.setdefault(a["rid"], []).append(
                (_ns(e.wall_s), "queued", e.pid))
        elif name == "engine.prefill" and ph == BEGIN:
            for rid in (a.get("rids") or ()):
                if rid in per:
                    per[rid].append((_ns(e.wall_s), "prefill_begin", e.pid))
        elif name == "engine.prefix_hit" and ph == INSTANT:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "prefill_begin", e.pid))
        elif name == "req.first_token" and ph == INSTANT:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "first_token", e.pid))
        elif name == "req.decode" and ph == INSTANT:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "decode", e.pid))
        elif name == "req.freeze" and ph == INSTANT:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "freeze", e.pid))
        elif name == "req.thaw" and ph == INSTANT:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "thaw", e.pid))
        elif name == "req.slot" and ph == END \
                and a.get("reason") in _TERMINAL_REASONS:
            if a.get("rid") in per:
                per[a["rid"]].append((_ns(e.wall_s), "finished", e.pid))
        elif name == "engine.oom" and ph == INSTANT:
            ooms.setdefault(e.pid, []).append(_ns(e.wall_s))
    return per, ooms


def _attribute_one(rid: int, ms: List[Tuple[int, str, str]],
                   ooms: Dict[str, List[int]]) -> RequestAttribution:
    comp = {c: 0 for c in COMPONENTS}
    t0 = ms[0][0]
    end = t0
    for i in range(len(ms) - 1):
        t, kind, pid = ms[i]
        t_next, kind_next, pid_next = ms[i + 1]
        if kind == "finished":
            break               # nothing past the terminal edge counts
        dur = t_next - t
        if kind == "queued":
            # an engine.oom during this wait means the tail of it was
            # admission backoff, not ordinary queueing
            cut = next((o for o in ooms.get(pid, ()) if t < o <= t_next),
                       None)
            if cut is None:
                comp["queue_wait"] += dur
            else:
                comp["queue_wait"] += cut - t
                comp["retry_backoff"] += t_next - cut
        elif kind == "prefill_begin":
            comp["prefill"] += dur
        elif kind in ("first_token", "decode", "thaw"):
            comp["decode"] += dur
        elif kind == "freeze":
            # a frozen blob thawing on a *different* engine crossed a
            # link — that interval is the offload transfer; same-engine
            # thaw (or a fallback re-prefill) is plain migration wait
            if kind_next == "thaw" and pid_next != pid:
                comp["offload_link"] += dur
            else:
                comp["migration"] += dur
        end = t_next
    complete = any(k == "finished" for _, k, _ in ms)
    return RequestAttribution(rid=rid, pid=ms[0][2], complete=complete,
                              begin_ns=t0, end_ns=end, components_ns=comp)


def attribute_requests(rec_or_events) -> Dict[int, RequestAttribution]:
    """Per-request critical-path attribution over a recorder (or raw
    event sequence).  Only requests whose ``req.queued`` instant was
    recorded are attributed."""
    evts = getattr(rec_or_events, "events", rec_or_events)
    per, ooms = _milestones(evts)
    return {rid: _attribute_one(rid, ms, ooms)
            for rid, ms in per.items()}


# ------------------------------------------------------- fleet rollup ------
@dataclass(frozen=True)
class DeviceAttribution:
    """Component totals over one device's requests (origin-engine
    grouping), plus which component — and therefore which level —
    dominates overall and in the latency tail (slowest ~5%, at least
    one request)."""
    pid: str
    requests: int
    components_ns: Dict[str, int]
    end_to_end_ns: int
    tail_p95_ns: int
    dominant: str
    tail_dominant: str

    @property
    def dominant_layer(self) -> str:
        return COMPONENT_LAYER[self.dominant]

    @property
    def tail_dominant_layer(self) -> str:
        return COMPONENT_LAYER[self.tail_dominant]

    def to_dict(self) -> Dict:
        return {"pid": self.pid, "requests": self.requests,
                "end_to_end_s": self.end_to_end_ns / NS_PER_S,
                "components_s": {c: v / NS_PER_S
                                 for c, v in self.components_ns.items()},
                "tail_p95_s": self.tail_p95_ns / NS_PER_S,
                "dominant": self.dominant,
                "dominant_layer": self.dominant_layer,
                "tail_dominant": self.tail_dominant,
                "tail_dominant_layer": self.tail_dominant_layer}


@dataclass(frozen=True)
class FleetAttribution:
    per_device: Dict[str, DeviceAttribution]
    per_tier: Dict[str, DeviceAttribution]
    fleet: DeviceAttribution

    def ranking(self) -> List[Tuple[str, int]]:
        """Components ranked by fleet-wide total (descending)."""
        return sorted(self.fleet.components_ns.items(),
                      key=lambda kv: -kv[1])

    def to_dict(self) -> Dict:
        return {"per_device": {p: d.to_dict()
                               for p, d in self.per_device.items()},
                "per_tier": {t: d.to_dict()
                             for t, d in self.per_tier.items()},
                "fleet": self.fleet.to_dict(),
                "ranking": [c for c, _ in self.ranking()]}


def _rollup(pid: str, attrs: List[RequestAttribution]) -> DeviceAttribution:
    comp = {c: 0 for c in COMPONENTS}
    for a in attrs:
        for c in COMPONENTS:
            comp[c] += a.components_ns[c]
    e2e = [a.end_to_end_ns for a in attrs]
    total = sum(e2e)
    dominant = max(COMPONENTS, key=lambda c: (comp[c],
                                              -COMPONENTS.index(c)))
    if attrs:
        order = sorted(attrs, key=lambda a: a.end_to_end_ns)
        k = max(1, math.ceil(0.05 * len(attrs)))
        tail = order[-k:]
        tail_p95 = order[min(len(order) - 1,
                             math.ceil(0.95 * len(order)) - 1)].end_to_end_ns
        tcomp = {c: sum(a.components_ns[c] for a in tail)
                 for c in COMPONENTS}
        tail_dom = max(COMPONENTS, key=lambda c: (tcomp[c],
                                                  -COMPONENTS.index(c)))
    else:
        tail_p95, tail_dom = 0, COMPONENTS[0]
    return DeviceAttribution(pid=pid, requests=len(attrs),
                             components_ns=comp, end_to_end_ns=total,
                             tail_p95_ns=tail_p95, dominant=dominant,
                             tail_dominant=tail_dom)


def attribute_fleet(rec_or_events,
                    tiers: Optional[Dict[str, str]] = None
                    ) -> FleetAttribution:
    """Fleet-level rollup: group per-request attributions by origin
    device (and by tier when a ``pid → tier`` mapping is supplied) and
    rank which component — which *level* — dominates overall and tail
    latency.  All totals are integer-ns sums of the per-request values,
    so they equal the per-request components exactly."""
    attrs = list(attribute_requests(rec_or_events).values())
    by_pid: Dict[str, List[RequestAttribution]] = {}
    by_tier: Dict[str, List[RequestAttribution]] = {}
    for a in attrs:
        by_pid.setdefault(a.pid, []).append(a)
        if tiers:
            by_tier.setdefault(tiers.get(a.pid, "unknown"), []).append(a)
    return FleetAttribution(
        per_device={p: _rollup(p, v) for p, v in sorted(by_pid.items())},
        per_tier={t: _rollup(t, v) for t, v in sorted(by_tier.items())},
        fleet=_rollup("fleet", attrs))
