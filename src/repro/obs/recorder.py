"""Structured span/event recording with dual timestamps.

Every event carries **two clocks**:

* ``wall_s`` — host ``time.perf_counter()`` at emission (always set);
* ``sim_s`` — the fleet's simulated clock, when a ``sim_clock``
  callable is installed (the :class:`FleetController` installs
  ``lambda: self._now``), else ``None``.

That pairing is what lets a heterogeneous fleet run render as ONE
timeline: engine decode ticks measured in wall microseconds and fleet
clock events measured in simulated seconds land on a shared timebase
(the exporter picks the simulated clock when every event has it).

Two recorders implement the same four-method surface:

* :class:`NullRecorder` — the default everywhere.  ``enabled`` is
  ``False`` and every method is a no-op ``pass``; hot paths guard arg
  construction behind ``if recorder.enabled`` so a disabled engine pays
  one attribute load per tick.
* :class:`TraceRecorder` — appends :class:`Event` rows to an in-memory
  list (bounded by ``capacity``), to be exported with
  :func:`repro.obs.export.write_trace` or queried with
  :mod:`repro.obs.query`.

Span discipline: ``begin``/``end`` pairs must nest per ``(pid, tid)``
track — pid is the device (or ``"fleet"`` for fleet-global events), tid
the slot/subsystem lane.  ``instant`` events never affect nesting.
``tests/test_obs.py`` property-pins well-nestedness and two-clock
monotonicity across decode modes and mid-run swap/drop events.

Layer categories (``cat``) — the four layers of the cross-level loop:

* ``"request"``   — request lifecycle (queued → admit → decode → finish)
* ``"engine"``    — engine steps, prefill calls, compiles, swaps
* ``"fleet"``     — device wakes, telemetry merges, recalibration,
                    loop decisions, drop/inject events
* ``"placement"`` — placement sweeps and per-requester decisions
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

# the four span layers; tools/check_trace.py can require all of them
LAYERS = ("request", "engine", "fleet", "placement")

# event phases (a subset of the Chrome trace-event phases)
BEGIN, END, INSTANT, COUNTER = "B", "E", "i", "C"


@dataclass(frozen=True)
class Event:
    """One recorded event.  ``ph`` is the Chrome-trace phase (``B``/``E``
    span edges, ``i`` instant, ``C`` counter); ``pid``/``tid`` name the
    process (device) and thread (slot/subsystem) tracks; ``args`` is a
    small JSON-serializable payload."""
    name: str
    cat: str
    ph: str
    wall_s: float
    sim_s: Optional[float]
    pid: str
    tid: str
    args: Optional[Dict[str, object]] = None


class NullRecorder:
    """The disabled recorder: every call is a no-op.  Hot paths check
    ``enabled`` before building args, so the per-tick cost of disabled
    observability is one attribute load and a branch."""

    enabled = False
    __slots__ = ()

    def begin(self, name: str, *, pid: str, tid: str, cat: str = "engine",
              wall_s: Optional[float] = None,
              args: Optional[Dict[str, object]] = None) -> None:
        pass

    def end(self, name: str, *, pid: str, tid: str, cat: str = "engine",
            wall_s: Optional[float] = None,
            args: Optional[Dict[str, object]] = None) -> None:
        pass

    def instant(self, name: str, *, pid: str, tid: str,
                cat: str = "engine", wall_s: Optional[float] = None,
                args: Optional[Dict[str, object]] = None) -> None:
        pass

    def counter(self, name: str, *, pid: str, tid: str = "counters",
                cat: str = "engine", value: float = 0.0,
                wall_s: Optional[float] = None) -> None:
        pass


# the shared default: safe to hand to any number of components because
# it is stateless
NULL_RECORDER = NullRecorder()


class TraceRecorder:
    """In-memory event recorder.

    ``sim_clock`` supplies the simulated-clock reading per event (the
    fleet controller installs its own ``_now``); without one, events
    carry ``sim_s=None`` and the exporter falls back to the wall clock.
    ``capacity`` bounds the event list — when full, recording *stops*
    (dropping the newest, never corrupting span nesting mid-trace) and
    ``dropped`` counts what was lost."""

    enabled = True

    def __init__(self, sim_clock: Optional[Callable[[], float]] = None,
                 capacity: int = 1_000_000):
        self.events: List[Event] = []
        self.sim_clock = sim_clock
        self.capacity = capacity
        self.dropped = 0

    # ------------------------------------------------------------- emit --
    def _emit(self, name: str, cat: str, ph: str, pid: str, tid: str,
              wall_s: Optional[float],
              args: Optional[Dict[str, object]]) -> None:
        if len(self.events) >= self.capacity:
            self.dropped += 1
            return
        self.events.append(Event(
            name=name, cat=cat, ph=ph,
            wall_s=time.perf_counter() if wall_s is None else wall_s,
            sim_s=self.sim_clock() if self.sim_clock is not None else None,
            pid=pid, tid=tid, args=args))

    def begin(self, name: str, *, pid: str, tid: str, cat: str = "engine",
              wall_s: Optional[float] = None,
              args: Optional[Dict[str, object]] = None) -> None:
        self._emit(name, cat, BEGIN, pid, tid, wall_s, args)

    def end(self, name: str, *, pid: str, tid: str, cat: str = "engine",
            wall_s: Optional[float] = None,
            args: Optional[Dict[str, object]] = None) -> None:
        self._emit(name, cat, END, pid, tid, wall_s, args)

    def instant(self, name: str, *, pid: str, tid: str,
                cat: str = "engine", wall_s: Optional[float] = None,
                args: Optional[Dict[str, object]] = None) -> None:
        self._emit(name, cat, INSTANT, pid, tid, wall_s, args)

    def counter(self, name: str, *, pid: str, tid: str = "counters",
                cat: str = "engine", value: float = 0.0,
                wall_s: Optional[float] = None) -> None:
        self._emit(name, cat, COUNTER, pid, tid, wall_s, {"value": value})

    # ------------------------------------------------------------ query --
    def __len__(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0
