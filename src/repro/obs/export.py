"""Chrome-trace / Perfetto export for recorded spans.

``chrome_trace`` renders a :class:`~repro.obs.recorder.TraceRecorder`'s
events as the Chrome trace-event JSON format (the ``traceEvents`` array
flavor), loadable in ``chrome://tracing`` and https://ui.perfetto.dev:

* pid = device (``process_name`` metadata carries the device id),
* tid = slot/subsystem lane (``thread_name`` metadata),
* ts  = microseconds on the chosen clock.

Clock selection (``clock=``):

* ``"auto"`` (default) — the simulated fleet clock when *every* event
  carries one (a fleet run), else the wall clock (a standalone engine).
  Mixing is never allowed: one timeline, one timebase.
* ``"sim"`` / ``"wall"`` — force a clock; ``"sim"`` raises if any event
  lacks a simulated timestamp.

Whichever clock becomes ``ts``, the other is preserved per-event in
``args`` (``wall_s`` or ``sim_s``), so the causal chain can always be
cross-referenced against the other timebase.
"""
from __future__ import annotations

import json
from typing import Dict, List

from .recorder import Event, TraceRecorder

CLOCKS = ("auto", "sim", "wall")


def _pick_clock(events: List[Event], clock: str) -> str:
    if clock not in CLOCKS:
        raise ValueError(f"unknown clock {clock!r}; expected one of {CLOCKS}")
    if clock == "auto":
        return ("sim" if events and all(e.sim_s is not None for e in events)
                else "wall")
    if clock == "sim" and any(e.sim_s is None for e in events):
        raise ValueError("clock='sim' but some events carry no simulated "
                         "timestamp (standalone-engine events?)")
    return clock


def chrome_trace(recorder: TraceRecorder, clock: str = "auto") -> Dict:
    """Render the recorder's events as a Chrome trace dict."""
    events = recorder.events
    chosen = _pick_clock(events, clock)
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    rows: List[Dict] = []
    stacks: Dict[tuple, List[Dict]] = {}    # open B rows per (pid, tid)
    last_ts: Dict[tuple, float] = {}
    orphaned_ends = 0
    for e in events:
        if e.pid not in pids:
            pids[e.pid] = len(pids) + 1
            rows.append({"name": "process_name", "ph": "M",
                         "pid": pids[e.pid], "tid": 0,
                         "args": {"name": e.pid}})
        tkey = (e.pid, e.tid)
        if tkey not in tids:
            tids[tkey] = len(tids) + 1
            rows.append({"name": "thread_name", "ph": "M",
                         "pid": pids[e.pid], "tid": tids[tkey],
                         "args": {"name": e.tid}})
        ts_s = e.sim_s if chosen == "sim" else e.wall_s
        if e.ph == "E" and not stacks.get((e.pid, e.tid)):
            # an END whose BEGIN aged out of a bounded ring / saturated
            # recorder: emitting it would fail span-discipline checks,
            # so count it instead — otherData carries the tally
            orphaned_ends += 1
            continue
        args = dict(e.args) if e.args else {}
        # preserve the other clock so either timebase can be recovered
        if chosen == "sim":
            args.setdefault("wall_s", e.wall_s)
        elif e.sim_s is not None:
            args.setdefault("sim_s", e.sim_s)
        row = {"name": e.name, "cat": e.cat, "ph": e.ph,
               "ts": ts_s * 1e6, "pid": pids[e.pid], "tid": tids[tkey]}
        if args:
            row["args"] = args
        rows.append(row)
        last_ts[tkey] = row["ts"]
        if e.ph == "B":
            stacks.setdefault(tkey, []).append(row)
        elif e.ph == "E":
            stack = stacks.get(tkey)
            if stack:
                stack.pop()
    # close spans still open at export (e.g. requests in flight when the
    # run's horizon ended): a snapshot mid-run must still be a complete,
    # validating trace.  Synthetic ends land at the track's last ts and
    # are marked so queries can tell them from real completions.
    for tkey, stack in stacks.items():
        for b in reversed(stack):
            rows.append({"name": b["name"], "cat": b["cat"], "ph": "E",
                         "ts": last_ts[tkey], "pid": b["pid"],
                         "tid": b["tid"],
                         "args": {"open_at_export": True}})
    return {"traceEvents": rows, "displayTimeUnit": "ms",
            "otherData": {"clock": chosen,
                          "dropped_events": recorder.dropped,
                          "orphaned_ends": orphaned_ends}}


def write_trace(recorder: TraceRecorder, path: str,
                clock: str = "auto") -> str:
    """Write ``chrome_trace(recorder)`` to ``path`` (returns ``path``).
    Open the file in Perfetto (https://ui.perfetto.dev → "Open trace
    file") or ``chrome://tracing``."""
    with open(path, "w") as f:
        # args may carry rich objects (VariantSpec, tuples of hosts):
        # stringify anything json doesn't know rather than failing a run
        # at export time
        json.dump(chrome_trace(recorder, clock=clock), f, default=str)
    return path
