"""SLO classes and burn-rate tracking — the observability layer's
feedback signal into the adaptation loop.

An :class:`SLOClass` names latency targets (TTFT / TPOT at p95 / p99);
an :class:`SLOTracker` folds the engine's per-request observations into
rolling windows and scores each as an SRE-style **burn rate**: for an
objective "pX ≤ target", the allowed violation fraction is ``1 - X``,
and

    burn = (observed violation fraction) / (1 - X)

so ``burn == 1`` means the error budget is being spent exactly as fast
as it accrues, and ``burn > 1`` means the SLO will be missed if the
window's behavior persists.  Each window also keeps a P² histogram of
the raw observations (:class:`~repro.obs.metrics.Histogram`, with its
serializable ``snapshot()`` marker state), so the same representation
flows into flight-recorder dumps and ``BENCH_*.json`` artifacts.

Events (``pid=obs_pid, tid="slo", cat="fleet"``):

* ``slo.burn``    — a window closed with burn above the page threshold;
* ``slo.page``    — pressure *engaged* (the pager fired): the
  :class:`~repro.fleet.controller.FleetController` reacts by pulling
  placement forward and biasing every loop toward cheaper variants;
* ``slo.release`` — pressure released after ``release_windows``
  consecutive healthy windows (hysteresis — one good window never
  un-pages).

While healthy, :meth:`update` is pure bookkeeping: it touches no RNG,
reorders nothing, and returns 0.0, so SLO-tracked fault-free runs stay
bit-identical to untracked ones (pinned in ``tests/test_obs.py``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .metrics import Histogram, MetricsRegistry
from .recorder import NULL_RECORDER

METRICS = ("ttft", "tpot")


@dataclass(frozen=True)
class SLOClass:
    """Latency targets for one service class.  ``None`` targets are
    untracked; at least one must be set."""
    name: str = "default"
    ttft_p95_s: Optional[float] = None
    ttft_p99_s: Optional[float] = None
    tpot_p95_s: Optional[float] = None
    tpot_p99_s: Optional[float] = None

    def objectives(self) -> List[Tuple[str, float, float]]:
        """``(metric, quantile, target_s)`` rows for the set targets."""
        out = []
        for metric, q, target in (("ttft", 0.95, self.ttft_p95_s),
                                  ("ttft", 0.99, self.ttft_p99_s),
                                  ("tpot", 0.95, self.tpot_p95_s),
                                  ("tpot", 0.99, self.tpot_p99_s)):
            if target is not None:
                out.append((metric, q, float(target)))
        if not out:
            raise ValueError(f"SLOClass {self.name!r} sets no targets")
        return out


class _Window:
    """One burn-rate window: per-metric P² histogram + exact violation
    counts per objective (counts, not quantile estimates, score the
    burn — the estimator summarizes, the counters decide)."""

    __slots__ = ("start_s", "hists", "counts", "bad")

    def __init__(self, start_s: float, objectives):
        self.start_s = start_s
        self.hists: Dict[str, Histogram] = {
            m: Histogram(f"slo.{m}_s") for m in METRICS}
        self.counts: Dict[str, int] = {m: 0 for m in METRICS}
        self.bad: Dict[Tuple[str, float], int] = {
            (m, q): 0 for m, q, _ in objectives}

    def observe(self, objectives, metric: str, value_s: float,
                n: int = 1) -> None:
        self.counts[metric] += n
        for _ in range(n):
            self.hists[metric].observe(value_s)
        for m, q, target in objectives:
            if m == metric and value_s > target:
                self.bad[(m, q)] += n

    def burn(self, objectives, min_count: int) -> float:
        worst = 0.0
        for m, q, _ in objectives:
            n = self.counts[m]
            if n < min_count:
                continue
            worst = max(worst, (self.bad[(m, q)] / n) / (1.0 - q))
        return worst

    def snapshot(self, objectives, min_count: int) -> Dict:
        return {"start_s": self.start_s,
                "burn": self.burn(objectives, min_count),
                "counts": dict(self.counts),
                "bad": {f"{m}_p{q * 100:g}": v
                        for (m, q), v in self.bad.items()},
                "hists": {m: h.snapshot() for m, h in self.hists.items()
                          if h.count}}


class SLOTracker:
    """Rolling burn-rate evaluation with hysteretic pressure.

    ``observe()`` is the engine-side feed (the engine calls it with
    TTFT at first token and per-token step time); ``update(now)`` is
    the controller-side consumption: it rotates windows on the fleet
    clock and returns the current **pressure** — 0.0 while healthy,
    ``max(burn, 1)`` while paging.  Pressure engages the moment burn
    crosses ``page_burn`` (live window included, so a load spike pages
    within one wake) and releases only after ``release_windows``
    consecutive *closed* windows at or below ``release_burn``."""

    def __init__(self, slo: SLOClass, *, window_s: float = 1.0,
                 min_count: int = 4, page_burn: float = 1.0,
                 release_burn: float = 0.5, release_windows: int = 2,
                 history: int = 32,
                 clock: Optional[Callable[[], float]] = None,
                 recorder=NULL_RECORDER,
                 metrics: Optional[MetricsRegistry] = None,
                 obs_pid: str = "fleet"):
        self.slo = slo
        self._objectives = slo.objectives()
        self.window_s = float(window_s)
        self.min_count = int(min_count)
        self.page_burn = float(page_burn)
        self.release_burn = float(release_burn)
        self.release_windows = int(release_windows)
        self.clock = clock if clock is not None else time.perf_counter
        self.recorder = recorder
        self.obs_pid = obs_pid
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._burn_gauge = self.metrics.gauge("slo.burn_rate")
        self._pressure_gauge = self.metrics.gauge("slo.pressure")
        self._page_counter = self.metrics.counter("slo.pages")
        self._burn_counter = self.metrics.counter("slo.burn_windows")
        self._live: Optional[_Window] = None
        self._last_closed_burn = 0.0
        self._healthy_streak = 0
        self.pressure = 0.0
        self.history: Deque[Dict] = deque(maxlen=history)

    # ------------------------------------------------------------ wiring --
    def bind(self, *, clock=None, recorder=None) -> None:
        """Adopt the fleet's clock/recorder (the controller calls this;
        an explicitly-configured recorder is kept)."""
        if clock is not None:
            self.clock = clock
        if recorder is not None and recorder.enabled \
                and not self.recorder.enabled:
            self.recorder = recorder

    # ------------------------------------------------------------- feed --
    def observe(self, metric: str, value_s: float, n: int = 1) -> None:
        """Record ``n`` observations of ``value_s`` for ``metric``
        (``"ttft"`` or ``"tpot"``) into the live window."""
        if metric not in METRICS:
            raise ValueError(f"unknown SLO metric {metric!r}; "
                             f"expected one of {METRICS}")
        if self._live is None:
            self._live = _Window(self.clock(), self._objectives)
        self._live.observe(self._objectives, metric, value_s, n)

    # ------------------------------------------------------- evaluation --
    def _close_window(self, w: _Window) -> None:
        burn = w.burn(self._objectives, self.min_count)
        self._last_closed_burn = burn
        self.history.append(w.snapshot(self._objectives, self.min_count))
        if burn > self.page_burn:
            self._burn_counter.inc()
            if self.recorder.enabled:
                self.recorder.instant(
                    "slo.burn", pid=self.obs_pid, tid="slo", cat="fleet",
                    args={"burn": burn, "slo": self.slo.name,
                          "window_start_s": w.start_s})
        if self.pressure > 0.0:
            if burn <= self.release_burn:
                self._healthy_streak += 1
            else:
                self._healthy_streak = 0

    def update(self, now_s: Optional[float] = None) -> float:
        """Rotate windows up to ``now``, re-evaluate burn, and return
        the current pressure.  Pure bookkeeping — safe to call on every
        fleet wake."""
        now = self.clock() if now_s is None else now_s
        while self._live is not None \
                and now - self._live.start_s >= self.window_s:
            w = self._live
            # an idle gap longer than one window closes as a single
            # (healthy) window instead of iterating through empty ones
            nxt = (w.start_s + self.window_s
                   if now - w.start_s < 2 * self.window_s else now)
            self._live = _Window(nxt, self._objectives)
            self._close_window(w)
        live_burn = (self._live.burn(self._objectives, self.min_count)
                     if self._live is not None else 0.0)
        burn = max(live_burn, self._last_closed_burn)
        self._burn_gauge.set(burn)
        if self.pressure == 0.0:
            if burn > self.page_burn:
                self.pressure = max(burn, 1.0)
                self._healthy_streak = 0
                self._page_counter.inc()
                if self.recorder.enabled:
                    self.recorder.instant(
                        "slo.page", pid=self.obs_pid, tid="slo",
                        cat="fleet",
                        args={"burn": burn, "slo": self.slo.name})
        else:
            if self._healthy_streak >= self.release_windows \
                    and burn <= self.release_burn:
                self.pressure = 0.0
                self._healthy_streak = 0
                if self.recorder.enabled:
                    self.recorder.instant(
                        "slo.release", pid=self.obs_pid, tid="slo",
                        cat="fleet",
                        args={"burn": burn, "slo": self.slo.name})
            else:
                self.pressure = max(burn, 1.0)
        self._pressure_gauge.set(self.pressure)
        return self.pressure

    def state(self) -> Dict:
        """Serializable tracker state (window history with full
        histogram snapshots) — what flight dumps and bench artifacts
        embed."""
        return {"slo": self.slo.name,
                "objectives": [{"metric": m, "q": q, "target_s": t}
                               for m, q, t in self._objectives],
                "window_s": self.window_s,
                "pressure": self.pressure,
                "burn": self._last_closed_burn,
                "windows": list(self.history)}
