"""The paper's six compression-operator families (η1…η6), adapted from
mobile CNNs to transformer supernets (DESIGN.md §Arch-applicability).

Every operator is a *retraining-free* transformation
``(cfg, params) -> (variant_cfg, variant_params)`` whose variant weights are
derived from (recycled out of) the backbone weights — slicing, SVD
factorization, head merging, ghost-feature mapping.  This is the paper's
"weight recycling across diverse variants": switching variants at runtime
never touches an optimizer.

  η1  low-rank factorization   (SVD of FFN/attention projections)
  η2  channel merging          (Fire/squeeze analogue: KV-head mean-merge)
  η3  composite scaling        (EfficientNet-style compound width/depth/window)
  η4  ghost features           (compute half the FFN hidden, map the rest)
  η5  depth scaling            (layer slicing + early exits)
  η6  channel scaling          (importance-ordered FFN + Q-head slicing)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.layers import Params

OPERATOR_NAMES = ("eta1", "eta2", "eta3", "eta4", "eta5", "eta6")


@dataclass(frozen=True)
class VariantSpec:
    """A point in the elastic action space θ_p."""
    rank_ratio: float = 1.0       # η1: SVD rank fraction of FFN projections
    kv_merge: int = 1             # η2: merge factor for KV heads
    compound: float = 0.0         # η3: EfficientNet-style φ (0 = off)
    ghost: bool = False           # η4: ghost-FFN on/off
    depth_ratio: float = 1.0      # η5: fraction of layers kept
    width_ratio: float = 1.0      # η6: fraction of FFN hidden kept
    head_ratio: float = 1.0       # η6: fraction of Q heads kept
    window: int = 0               # window override (0 = arch default)

    def operators(self) -> Tuple[str, ...]:
        ops = []
        if self.rank_ratio < 1.0:
            ops.append("eta1")
        if self.kv_merge > 1:
            ops.append("eta2")
        if self.compound > 0:
            ops.append("eta3")
        if self.ghost:
            ops.append("eta4")
        if self.depth_ratio < 1.0:
            ops.append("eta5")
        if self.width_ratio < 1.0 or self.head_ratio < 1.0:
            ops.append("eta6")
        return tuple(ops)

    def replace(self, **kw) -> "VariantSpec":
        return dataclasses.replace(self, **kw)


FULL_SPEC = VariantSpec()

# named combinations used throughout the paper's tables (η1+η6 etc.)
NAMED_COMBOS: Dict[str, VariantSpec] = {
    "eta1+eta6": VariantSpec(rank_ratio=0.5, width_ratio=0.5),
    "eta2+eta6": VariantSpec(kv_merge=2, width_ratio=0.5),
    "eta1+eta5": VariantSpec(rank_ratio=0.5, depth_ratio=0.75),
    "eta2+eta5": VariantSpec(kv_merge=2, depth_ratio=0.75),
    "eta4+eta6": VariantSpec(ghost=True, width_ratio=0.75),
    "eta3": VariantSpec(compound=1.0),
}


def _round8(x: float) -> int:
    return max(8, int(round(x / 8)) * 8)


# --------------------------------------------------------------- η helpers --
def _svd_factor(w: np.ndarray, rank: int) -> Dict[str, np.ndarray]:
    u, s, vt = np.linalg.svd(np.asarray(w, np.float32), full_matrices=False)
    rank = min(rank, len(s))
    return {"u": (u[:, :rank] * s[:rank]).astype(w.dtype),
            "v": vt[:rank].astype(w.dtype)}


def _ffn_channel_importance(layer_ffn: Dict[str, np.ndarray]) -> np.ndarray:
    """Per-hidden-channel importance = ||w_up col|| * ||w_down row||."""
    up = np.asarray(layer_ffn["w_up"], np.float32)
    down = np.asarray(layer_ffn["w_down"], np.float32)
    imp = np.linalg.norm(up, axis=0) * np.linalg.norm(down, axis=1)
    if "w_gate" in layer_ffn:
        imp = imp * np.linalg.norm(np.asarray(layer_ffn["w_gate"], np.float32),
                                   axis=0)
    return imp


def _head_importance(wo: np.ndarray, num_heads: int, head_dim: int
                     ) -> np.ndarray:
    wo = np.asarray(wo, np.float32).reshape(num_heads, head_dim, -1)
    return np.linalg.norm(wo.reshape(num_heads, -1), axis=1)


# ------------------------------------------------------------ the operators --
def apply_eta1_lowrank(cfg: ModelConfig, layers: Params, ratio: float
                       ) -> Params:
    """SVD-factorize stacked FFN up/gate/down projections to rank r."""
    out = dict(layers)
    ffn = dict(layers["ffn"])
    d, f = cfg.d_model, cfg.d_ff
    rank = _round8(ratio * (d * f) / (d + f))  # FLOP-equalized rank
    for name in ("w_gate", "w_up", "w_down"):
        if name not in ffn or isinstance(ffn[name], dict):
            continue
        w = np.asarray(ffn[name])               # (L, din, dout)
        us, vs = [], []
        for li in range(w.shape[0]):
            fac = _svd_factor(w[li], rank)
            us.append(fac["u"])
            vs.append(fac["v"])
        ffn[name] = {"u": jnp.asarray(np.stack(us)),
                     "v": jnp.asarray(np.stack(vs))}
    out["ffn"] = ffn
    return out


def apply_eta2_kv_merge(cfg: ModelConfig, layers: Params, merge: int
                        ) -> Tuple[ModelConfig, Params]:
    """Mean-merge groups of KV heads (GQA-ification, retraining-free)."""
    if cfg.num_kv_heads % merge:
        raise ValueError(f"kv={cfg.num_kv_heads} not divisible by {merge}")
    new_kv = cfg.num_kv_heads // merge
    hd = cfg.resolved_head_dim
    out = dict(layers)
    attn = dict(layers["attn"])
    for name in ("wk", "wv"):
        w = np.asarray(attn[name])               # (L, d, kv*hd)
        l, d, _ = w.shape
        w = w.reshape(l, d, new_kv, merge, hd).mean(axis=3).reshape(
            l, d, new_kv * hd)
        attn[name] = jnp.asarray(w)
    for name in ("bk", "bv"):
        if name in attn:
            b = np.asarray(attn[name]).reshape(-1, new_kv, merge, hd)
            attn[name] = jnp.asarray(b.mean(axis=2).reshape(-1, new_kv * hd))
    out["attn"] = attn
    return cfg.with_updates(num_kv_heads=new_kv), out


def apply_eta4_ghost(cfg: ModelConfig, layers: Params) -> Tuple[ModelConfig, Params]:
    """GhostNet-style FFN: keep the important half of hidden channels,
    generate the dropped half as scaled copies of their nearest kept
    channel (cosine similarity of w_up columns)."""
    out = dict(layers)
    ffn = dict(layers["ffn"])
    f = cfg.d_ff
    keep_n = f // 2
    w_up = np.asarray(ffn["w_up"], np.float32)            # (L, d, f)
    l = w_up.shape[0]
    imp = np.stack([_ffn_channel_importance(
        {k: np.asarray(v)[li] for k, v in ffn.items() if not isinstance(v, dict)})
        for li in range(l)])                              # (L, f)
    keep = np.argsort(-imp, axis=1)[:, :keep_n]           # (L, keep_n)
    drop = np.argsort(-imp, axis=1)[:, keep_n:]
    src_idx, scales = [], []
    new = {k: [] for k in ffn}
    for li in range(l):
        cols = w_up[li][:, keep[li]]                      # (d, keep)
        cols_n = cols / (np.linalg.norm(cols, axis=0, keepdims=True) + 1e-9)
        dcols = w_up[li][:, drop[li]]
        sim = cols_n.T @ dcols                            # (keep, drop)
        nearest = np.argmax(np.abs(sim), axis=0)
        # least-squares scale: <kept, dropped> / <kept, kept>
        kn = cols[:, nearest]
        sc = (kn * dcols).sum(0) / ((kn * kn).sum(0) + 1e-9)
        src_idx.append(nearest)
        scales.append(sc)
        order = np.concatenate([keep[li], drop[li]])
        for name in ("w_gate", "w_up"):
            if name in ffn:
                new[name].append(np.asarray(ffn[name])[li][:, keep[li]])
        new["w_down"].append(np.asarray(ffn["w_down"])[li][order, :])
    ffn2 = {}
    for name in ("w_gate", "w_up"):
        if name in ffn:
            ffn2[name] = jnp.asarray(np.stack(new[name]))
    ffn2["w_down"] = jnp.asarray(np.stack(new["w_down"]))
    ffn2["ghost_src"] = jnp.asarray(np.stack(src_idx), jnp.int32)
    ffn2["ghost_scale"] = jnp.asarray(np.stack(scales), jnp.float32)
    out["ffn"] = ffn2
    return cfg, out


def apply_eta5_depth(cfg: ModelConfig, params: Params, ratio: float
                     ) -> Tuple[ModelConfig, Params]:
    """Keep the first ceil(ratio*L) layers (stacked-weight slicing)."""
    n = max(1, int(round(cfg.num_layers * ratio)))
    out = dict(params)
    out["layers"] = jax.tree_util.tree_map(lambda a: a[:n], params["layers"])
    return cfg.with_updates(num_layers=n), out


def apply_eta6_channels(cfg: ModelConfig, layers: Params, width_ratio: float,
                        head_ratio: float) -> Tuple[ModelConfig, Params]:
    """Importance-ordered FFN-hidden and Q-head slicing."""
    out = dict(layers)
    new_cfg = cfg
    if width_ratio < 1.0 and "ffn" in layers and cfg.d_ff:
        ffn = dict(layers["ffn"])
        f2 = _round8(cfg.d_ff * width_ratio)
        w_up = np.asarray(ffn["w_up"], np.float32)
        l = w_up.shape[0]
        idx = []
        for li in range(l):
            imp = _ffn_channel_importance(
                {k: np.asarray(v)[li] for k, v in ffn.items()
                 if not isinstance(v, dict)})
            idx.append(np.argsort(-imp)[:f2])
        for name in ("w_gate", "w_up"):
            if name in ffn:
                w = np.asarray(ffn[name])
                ffn[name] = jnp.asarray(
                    np.stack([w[li][:, idx[li]] for li in range(l)]))
        wd = np.asarray(ffn["w_down"])
        ffn["w_down"] = jnp.asarray(
            np.stack([wd[li][idx[li], :] for li in range(l)]))
        out["ffn"] = ffn
        new_cfg = new_cfg.with_updates(d_ff=f2)
    if head_ratio < 1.0 and cfg.num_heads:
        hd = cfg.resolved_head_dim
        g = cfg.num_heads // cfg.num_kv_heads
        # prune whole GQA groups to keep grouping valid
        new_kvh = max(1, int(round(cfg.num_kv_heads * head_ratio)))
        new_h = new_kvh * g
        attn = dict(out.get("attn", layers["attn"]))
        wo = np.asarray(attn["wo"])               # (L, H*hd, d)
        l = wo.shape[0]
        kv_imp = np.stack([
            _head_importance(wo[li], cfg.num_heads, hd)
            .reshape(cfg.num_kv_heads, g).sum(1) for li in range(l)])
        kv_keep = np.argsort(-kv_imp, axis=1)[:, :new_kvh]  # (L, new_kvh)
        def take_heads(w, heads_per_kv, n_kv):
            # w: (L, d, n_kv*heads_per_kv*hd) -> keep kv groups
            d = w.shape[1]
            w = w.reshape(l, d, n_kv, heads_per_kv * hd)
            return np.stack([w[li][:, kv_keep[li]] for li in range(l)]
                            ).reshape(l, d, new_kvh * heads_per_kv * hd)
        attn["wq"] = jnp.asarray(take_heads(np.asarray(attn["wq"]), g,
                                            cfg.num_kv_heads))
        attn["wk"] = jnp.asarray(take_heads(np.asarray(attn["wk"]), 1,
                                            cfg.num_kv_heads))
        attn["wv"] = jnp.asarray(take_heads(np.asarray(attn["wv"]), 1,
                                            cfg.num_kv_heads))
        wo = wo.reshape(l, cfg.num_kv_heads, g * hd, -1)
        attn["wo"] = jnp.asarray(np.stack(
            [wo[li][kv_keep[li]] for li in range(l)]).reshape(
                l, new_h * hd, -1))
        for name, per in (("bq", g), ("bk", 1), ("bv", 1)):
            if name in attn:
                bias = np.asarray(attn[name]).reshape(l, cfg.num_kv_heads,
                                                      per * hd)
                attn[name] = jnp.asarray(np.stack(
                    [bias[li][kv_keep[li]] for li in range(l)]).reshape(l, -1))
        out["attn"] = attn
        new_cfg = new_cfg.with_updates(num_heads=new_h, num_kv_heads=new_kvh)
    return new_cfg, out


# ------------------------------------------------------------- entry point --
def derive_variant(cfg: ModelConfig, params: Params, spec: VariantSpec
                   ) -> Tuple[ModelConfig, Params]:
    """Materialize an elastic variant (cfg', params') from the backbone.

    Operators inapplicable to a family (e.g. FFN ops on an attention-free
    SSM) are skipped — matching DESIGN.md §Arch-applicability.
    """
    if spec.compound > 0:
        # η3 compound scaling: α^φ depth, β^φ width (α=0.8, β=0.8)
        spec = spec.replace(
            depth_ratio=min(spec.depth_ratio, 0.8 ** spec.compound),
            width_ratio=min(spec.width_ratio, 0.8 ** spec.compound),
            compound=0.0)
    new_cfg, new_params = cfg, dict(params)
    if spec.depth_ratio < 1.0:
        new_cfg, new_params = apply_eta5_depth(new_cfg, new_params,
                                               spec.depth_ratio)
    has_ffn = new_cfg.d_ff > 0 and new_cfg.arch_type not in ("ssm", "moe")
    has_attn = new_cfg.num_heads > 0 and new_cfg.arch_type not in ("ssm",)
    layers = new_params["layers"]
    if (spec.width_ratio < 1.0 and has_ffn) or (spec.head_ratio < 1.0 and has_attn):
        wr = spec.width_ratio if has_ffn else 1.0
        hr = spec.head_ratio if has_attn and new_cfg.arch_type == "dense" else 1.0
        new_cfg, layers = apply_eta6_channels(new_cfg, layers, wr, hr)
    if spec.kv_merge > 1 and has_attn and new_cfg.arch_type == "dense":
        new_cfg, layers = apply_eta2_kv_merge(new_cfg, layers, spec.kv_merge)
    if spec.ghost and has_ffn:
        new_cfg, layers = apply_eta4_ghost(new_cfg, layers)
    if spec.rank_ratio < 1.0 and has_ffn and "ghost_src" not in layers.get(
            "ffn", {}):
        layers = apply_eta1_lowrank(new_cfg, layers, spec.rank_ratio)
    new_params["layers"] = layers
    if spec.window:
        new_cfg = new_cfg.with_updates(sliding_window=spec.window)
    return new_cfg, new_params


def variant_cost(cfg: ModelConfig, spec: VariantSpec, seq_len: int = 2048
                 ) -> Dict[str, float]:
    """Analytic cost of a variant (no materialization) — used by the
    middleware optimizer to napkin-math candidates before deriving them."""
    c = cfg
    if spec.compound > 0:
        spec = spec.replace(depth_ratio=0.8 ** spec.compound,
                            width_ratio=0.8 ** spec.compound, compound=0.0)
    if spec.depth_ratio < 1.0:
        c = c.with_updates(num_layers=max(1, int(round(c.num_layers
                                                       * spec.depth_ratio))))
    if spec.width_ratio < 1.0 and c.d_ff:
        c = c.with_updates(d_ff=_round8(c.d_ff * spec.width_ratio))
    if spec.head_ratio < 1.0 and c.num_heads and c.arch_type == "dense":
        g = c.num_heads // c.num_kv_heads
        nk = max(1, int(round(c.num_kv_heads * spec.head_ratio)))
        c = c.with_updates(num_kv_heads=nk, num_heads=nk * g)
    if spec.kv_merge > 1 and c.num_kv_heads and c.arch_type == "dense":
        c = c.with_updates(num_kv_heads=max(1, c.num_kv_heads // spec.kv_merge))
    flops = c.flops_per_token(seq_len)
    if spec.rank_ratio < 1.0 and c.d_ff:
        d, f = c.d_model, c.d_ff
        rank = _round8(spec.rank_ratio * (d * f) / (d + f))
        mats = 3 if c.gated_ffn else 2
        dense_ffn = 2.0 * mats * d * f
        lr_ffn = 2.0 * mats * rank * (d + f)
        flops = flops - c.num_layers * (dense_ffn - lr_ffn)
    if spec.ghost and c.d_ff:
        mats = 2 if c.gated_ffn else 1  # up(+gate) halved, down unchanged
        flops = flops - c.num_layers * mats * c.d_model * c.d_ff  # 2*(f/2)
    return {
        "flops_per_token": float(flops),
        "params": float(c.param_count()
                        * (spec.rank_ratio if spec.rank_ratio < 1 else 1.0)),
        "kv_bytes_per_token": float(c.kv_cache_bytes(1, 1)),
    }
