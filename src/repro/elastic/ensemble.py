"""Offline ensemble training of backbone + variants (paper §III-A1).

The paper moves retraining into a one-time ensemble-training phase: the
backbone is trained to high accuracy, then variants are co-trained with
weight recycling so that any runtime subset keeps accuracy.  Here the
variants ARE slices of the backbone (supernet), so ensemble training is
sandwich-style (slimmable networks): each step trains the full model, the
smallest variant, and random intermediate variants, with the full model
distilling into the slices.  Gradients flow into the same backbone tensors
— that is the weight recycling.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import Params
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions
from repro.models.transformer import forward, lm_loss

from .operators import FULL_SPEC, VariantSpec


def sliced_forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   spec: VariantSpec, opts: RuntimeOptions = DEFAULT_OPTIONS
                   ) -> jax.Array:
    """Forward through a *differentiable* weight-recycled slice.

    Unlike ``derive_variant`` (numpy, importance-ordered — for inference),
    this takes prefix slices so gradients flow into the backbone tensors:
    depth -> first n layers, width -> first k FFN channels.  Prefix slicing
    during ensemble training is what MAKES prefix channels the important
    ones at inference (OFA/slimmable training convention).
    """
    p = dict(params)
    n_layers = max(1, int(round(cfg.num_layers * spec.depth_ratio)))
    vcfg = cfg
    layers = params["layers"]
    if spec.width_ratio < 1.0 and cfg.d_ff and cfg.arch_type == "dense":
        f2 = max(8, int(cfg.d_ff * spec.width_ratio) // 8 * 8)
        ffn = {k: (v[:, :, :f2] if k in ("w_up", "w_gate") else v[:, :f2, :])
               for k, v in layers["ffn"].items()}
        layers = {**layers, "ffn": ffn}
        vcfg = vcfg.with_updates(d_ff=f2)
    p["layers"] = layers
    logits, _ = forward(p, vcfg, tokens, opts, num_layers=n_layers)
    return logits


def ensemble_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  labels: jax.Array, key: jax.Array,
                  specs: Sequence[VariantSpec] = (),
                  distill_weight: float = 0.5,
                  opts: RuntimeOptions = DEFAULT_OPTIONS) -> jax.Array:
    """Sandwich-rule ensemble loss: full + smallest + sampled variants.

    The full model trains on data; variants train on data + KL-distillation
    from the (stop-gradient) full model.
    """
    full_logits, aux = forward(params, cfg, tokens, opts)
    loss = lm_loss(full_logits, labels) + cfg.router_aux_weight * aux
    teacher = jax.lax.stop_gradient(
        jax.nn.log_softmax(full_logits.astype(jnp.float32), axis=-1))
    if not specs:
        specs = (VariantSpec(depth_ratio=0.5, width_ratio=0.5),)
    for spec in specs:
        v_logits = sliced_forward(params, cfg, tokens, spec, opts)
        v_loss = lm_loss(v_logits, labels)
        logq = jax.nn.log_softmax(v_logits.astype(jnp.float32), axis=-1)
        kl = jnp.mean(jnp.sum(jnp.exp(teacher) * (teacher - logq), axis=-1))
        loss = loss + (1 - distill_weight) * v_loss + distill_weight * kl
    return loss / (1 + len(specs))


def sample_variant_specs(key: jax.Array, n: int = 2) -> Tuple[VariantSpec, ...]:
    """Random intermediate variants for the sandwich rule."""
    keys = jax.random.split(key, n)
    specs = []
    for k in keys:
        d, w = jax.random.uniform(k, (2,), minval=0.5, maxval=1.0)
        specs.append(VariantSpec(depth_ratio=float(jnp.round(d * 4) / 4),
                                 width_ratio=float(jnp.round(w * 4) / 4)))
    return tuple(specs)
