"""Multi-branch early exits (paper §III-A1).

Exit heads (norm + linear-to-vocab via the tied embedding) are attached at
chosen depths of the backbone.  At inference, per-example confidence
(max softmax prob) against a threshold decides the exit — realized with
masking so the whole batch stays a single jit region (no data-dependent
shapes), which is the TPU-idiomatic version of the paper's branch exits.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import Params, cast_params, dtype_of, embed_lookup, rms_norm, unembed
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions
from repro.models.transformer import _pattern_period, apply_stack


def attach_exits(cfg: ModelConfig, params: Params, key: jax.Array,
                 positions: Sequence[int]) -> Params:
    """Add exit-head parameters at the given layer indices."""
    out = dict(params)
    dtype = dtype_of(cfg.param_dtype)
    out["exits"] = {
        "positions": tuple(int(p) for p in positions),
        "norms": jnp.zeros((len(positions), cfg.d_model), dtype),
    }
    return out


def forward_with_exits(params: Params, cfg: ModelConfig, tokens: jax.Array,
                       opts: RuntimeOptions = DEFAULT_OPTIONS
                       ) -> List[jax.Array]:
    """Return logits at every exit position plus the final head.

    Runs the stack in segments between exit positions (segments still scan).
    """
    act_dt = dtype_of(cfg.activation_dtype)
    ps = cast_params(params, act_dt)
    x = embed_lookup(ps["embed"], tokens).astype(act_dt)
    positions = list(params["exits"]["positions"]) if "exits" in params else []
    bounds = positions + [cfg.num_layers]
    start = 0
    outs = []
    stack = ps["layers"]
    for i, end in enumerate(bounds):
        seg = jax.tree_util.tree_map(lambda a: a[start:end], stack)
        if end > start:
            x, _ = apply_stack(seg, x, cfg, opts,
                               shared=ps.get("shared_attn"))
        if i < len(positions):
            h = rms_norm(x, ps["exits"]["norms"][i], cfg.norm_eps)
            outs.append(unembed(ps["embed"], h))
        start = end
    h = rms_norm(x, ps["final_norm"], cfg.norm_eps)
    outs.append(unembed(ps["embed"], h))
    return outs


def early_exit_predict(params: Params, cfg: ModelConfig, tokens: jax.Array,
                       threshold: float = 0.7,
                       opts: RuntimeOptions = DEFAULT_OPTIONS
                       ) -> Tuple[jax.Array, jax.Array]:
    """Batched adaptive early exit.

    Returns (logits (B,S,V), exit_depth (B,S) — index of the exit taken).
    Confidence = max softmax probability of the exit head; once an example
    clears the threshold its logits are frozen (masking semantics).
    """
    outs = forward_with_exits(params, cfg, tokens, opts)
    n = len(outs)
    logits = outs[-1].astype(jnp.float32)
    chosen = jnp.full(logits.shape[:-1], n - 1, jnp.int32)
    done = jnp.zeros(logits.shape[:-1], bool)
    result = logits
    for i, lg in enumerate(outs[:-1]):
        lg = lg.astype(jnp.float32)
        conf = jnp.max(jax.nn.softmax(lg, axis=-1), axis=-1)
        take = (conf >= threshold) & ~done
        result = jnp.where(take[..., None], lg, result)
        chosen = jnp.where(take, i, chosen)
        done = done | take
    return result, chosen


def expected_exit_flops(cfg: ModelConfig, exit_depth: jax.Array,
                        positions: Sequence[int], seq_len: int) -> float:
    """Average per-token FLOPs given realized exit depths (for the profiler)."""
    bounds = list(positions) + [cfg.num_layers]
    per_layer = cfg.flops_per_token(seq_len) / max(cfg.num_layers, 1)
    depths = jnp.asarray([bounds[i] for i in range(len(bounds))])
    used = jnp.take(depths, exit_depth)
    return float(jnp.mean(used) * per_layer)
