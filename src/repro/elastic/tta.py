"""Test-time adaptation for data drift (paper §III-A2).

Unsupervised entropy minimization that updates ONLY normalization scales
(TENT-style) — the selective-weight-update strategy the paper uses so that
adaptation is cheap enough to run inside the serving loop.  The backend
engine's TTA optimizations (§III-C2: reordered backprop, activation
compression, sub-batch accumulation) surface here as options.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import Params
from repro.models.runtime import DEFAULT_OPTIONS, RuntimeOptions
from repro.models.transformer import forward

NORM_KEYS = ("ln", "ln1", "ln2", "ln_cross", "final_norm", "norm_scale",
             "encoder_norm", "logit_bias")


def _is_norm_path(path) -> bool:
    names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
    return any(n in NORM_KEYS for n in names)


def split_norm_params(params: Params) -> Tuple[Params, Params]:
    """(adaptable norm scales, frozen rest) as same-structure masks."""
    norm = jax.tree_util.tree_map_with_path(
        lambda p, a: a if _is_norm_path(p) else None, params)
    return norm


def prediction_entropy(logits: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(jnp.exp(logp) * logp, axis=-1))


def tta_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
             opts: RuntimeOptions = DEFAULT_OPTIONS,
             objective: str = "entropy", **fwd_kw) -> jax.Array:
    """Unsupervised adaptation objective on unlabeled live tokens.

    "entropy" — TENT-style prediction-entropy minimization (the paper's
    classifier setting); "self" — next-token loss on the live stream
    itself, which for an LM is the natural label-free objective (live
    tokens ARE their own supervision)."""
    logits, _ = forward(params, cfg, tokens, opts, **fwd_kw)
    if objective == "self":
        from repro.models.transformer import lm_loss
        return lm_loss(logits[:, :-1], tokens[:, 1:])
    return prediction_entropy(logits)


def tta_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
             lr: float = 1e-3, opts: RuntimeOptions = DEFAULT_OPTIONS,
             sub_batches: int = 1, objective: str = "entropy",
             **fwd_kw) -> Tuple[Params, jax.Array]:
    """One TTA update on unlabeled live tokens.

    ``sub_batches > 1`` accumulates gradients over batch slices (the
    engine's ❽ memory-swapping / sub-batch accumulation strategy) so peak
    activation memory shrinks by ~sub_batches at equal statistical effect.
    Only norm scales receive updates; everything else is structurally
    frozen by zero-masking the gradient.
    """
    b = tokens.shape[0]
    assert b % sub_batches == 0
    step = b // sub_batches
    if "logit_bias" not in params:
        # lazily attach the adaptable output-prior vector
        params = dict(params)
        params["logit_bias"] = jnp.zeros((cfg.padded_vocab,), jnp.float32)

    def loss_fn(p, tok, kw):
        return tta_loss(p, cfg, tok, opts, objective=objective, **kw)

    grads = None
    total = 0.0
    for i in range(sub_batches):
        sl = slice(i * step, (i + 1) * step)
        kw = {k: (v[sl] if hasattr(v, "shape") else v)
              for k, v in fwd_kw.items()}
        l, g = jax.value_and_grad(loss_fn)(params, tokens[sl], kw)
        total += l / sub_batches
        g = jax.tree_util.tree_map(lambda a: a / sub_batches, g)
        grads = g if grads is None else jax.tree_util.tree_map(
            jnp.add, grads, g)

    def update(path, p, g):
        if _is_norm_path(path) and jnp.issubdtype(p.dtype, jnp.floating):
            names = [str(getattr(k, "key", "")) for k in path]
            # the output-prior bias sees (p_model - p_live)-scale gradients
            # (~1/V per entry): give it a proportionally larger step so the
            # log-prior can actually move within a few adaptation ticks
            eta = lr * 100.0 if "logit_bias" in names else lr
            return (p.astype(jnp.float32)
                    - eta * g.astype(jnp.float32)).astype(p.dtype)
        return p

    new_params = jax.tree_util.tree_map_with_path(update, params, grads)
    return new_params, total
