from .operators import (FULL_SPEC, NAMED_COMBOS, OPERATOR_NAMES, VariantSpec,
                        derive_variant, variant_cost)
from .supernet import ElasticSupernet
from .early_exit import attach_exits, early_exit_predict, forward_with_exits
from .ensemble import ensemble_loss, sample_variant_specs, sliced_forward
from .tta import tta_loss, tta_step

__all__ = ["FULL_SPEC", "NAMED_COMBOS", "OPERATOR_NAMES", "VariantSpec",
           "derive_variant", "variant_cost", "ElasticSupernet",
           "attach_exits", "early_exit_predict", "forward_with_exits",
           "ensemble_loss", "sample_variant_specs", "sliced_forward",
           "tta_loss", "tta_step"]
