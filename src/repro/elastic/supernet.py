"""Weight-recycled supernet: runtime variant selection without retraining.

The paper pre-assembles a multi-variant model whose variants share (recycle)
backbone weights so that switching compression level at runtime needs no
retraining (§III-A1).  Here the backbone IS the supernet: variants are
derived on demand by ``derive_variant`` and cached; switching variants is a
dictionary lookup + (on first use) one recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import Params

from .operators import FULL_SPEC, VariantSpec, derive_variant, variant_cost


class ElasticSupernet:
    """Holds one backbone and materializes/caches its elastic variants."""

    def __init__(self, cfg: ModelConfig, params: Params,
                 max_cached: int = 8):
        self.backbone_cfg = cfg
        self.backbone_params = params
        self.max_cached = max_cached
        self._cache: Dict[VariantSpec, Tuple[ModelConfig, Params]] = {}

    def variant(self, spec: VariantSpec) -> Tuple[ModelConfig, Params]:
        if spec == FULL_SPEC:
            return self.backbone_cfg, self.backbone_params
        if spec not in self._cache:
            if len(self._cache) >= self.max_cached:
                # evict the least recently inserted (simple FIFO)
                self._cache.pop(next(iter(self._cache)))
            self._cache[spec] = derive_variant(self.backbone_cfg,
                                               self.backbone_params, spec)
        return self._cache[spec]

    def cost(self, spec: VariantSpec, seq_len: int = 2048):
        return variant_cost(self.backbone_cfg, spec, seq_len)

    def applicable_operators(self) -> Tuple[str, ...]:
        """Which η families apply to this backbone (DESIGN.md §Arch-applic.)."""
        t = self.backbone_cfg.arch_type
        if t == "ssm":
            return ("eta5",)              # depth only: no FFN, no attention
        if t == "moe":
            return ("eta5", "eta6")       # expert/top-k scaling + depth
        if t == "hybrid":
            return ("eta5",)
        return ("eta1", "eta2", "eta3", "eta4", "eta5", "eta6")

    def action_space(self) -> Tuple[VariantSpec, ...]:
        """The discrete variant grid the middleware optimizer searches."""
        ops = set(self.applicable_operators())
        specs = [FULL_SPEC]
        if "eta5" in ops:
            specs += [VariantSpec(depth_ratio=r) for r in (0.75, 0.5)]
        if "eta6" in ops:
            specs += [VariantSpec(width_ratio=r) for r in (0.75, 0.5)]
        if "eta1" in ops:
            specs += [VariantSpec(rank_ratio=r) for r in (0.5, 0.25)]
        if "eta4" in ops:
            specs += [VariantSpec(ghost=True)]
        if "eta2" in ops and self.backbone_cfg.num_kv_heads % 2 == 0 \
                and self.backbone_cfg.num_kv_heads > 1:
            specs += [VariantSpec(kv_merge=2)]
        if "eta3" in ops:
            specs += [VariantSpec(compound=1.0)]
        # the paper's favored pairings
        if {"eta1", "eta6"} <= ops:
            specs += [VariantSpec(rank_ratio=0.5, width_ratio=0.5)]
        if {"eta1", "eta5"} <= ops:
            specs += [VariantSpec(rank_ratio=0.5, depth_ratio=0.75)]
        if {"eta5", "eta6"} <= ops:
            specs += [VariantSpec(depth_ratio=0.75, width_ratio=0.75)]
        return tuple(dict.fromkeys(specs))
