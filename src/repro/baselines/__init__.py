"""Baselines from the paper's evaluation (§IV-A), adapted to the
transformer substrate.

Handcrafted compression:
  * Fire / SqueezeNet  -> fixed squeeze-expand (KV merge + width 0.5)
  * SVD                -> fixed low-rank factorization (rank 0.5)
  * MobileNetV2        -> fixed inverted-bottleneck analogue (rank 0.75 +
                          ghost features)
On-demand compression:
  * AdaDeep            -> greedy operator combination under a latency budget
  * Once-for-all (OFA) -> supernet sampling, best accuracy under constraint
Partition/offloading baselines (CAS, DADS) live in repro.offload.placer.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.core.monitor import ResourceContext
from repro.core.optimizer import ActionEvaluator
from repro.core.actions import Action
from repro.elastic.operators import FULL_SPEC, VariantSpec, variant_cost
from repro.models.configs import InputShape, ModelConfig

HANDCRAFTED: Dict[str, VariantSpec] = {
    "fire": VariantSpec(kv_merge=2, width_ratio=0.5),
    "svd": VariantSpec(rank_ratio=0.5),
    "mobilenetv2": VariantSpec(rank_ratio=0.75, ghost=True),
}


def adadeep_select(cfg: ModelConfig, shape: InputShape,
                   latency_budget_s: float,
                   evaluator: Optional[ActionEvaluator] = None,
                   ctx: Optional[ResourceContext] = None) -> VariantSpec:
    """AdaDeep: greedily stack compression operators until the latency
    budget is met, preferring the operator with the best predicted
    accuracy-per-latency gain (a meta-learner in the paper; a profiler-
    guided greedy here)."""
    ev = evaluator or ActionEvaluator(cfg, shape)
    ctx = ctx or ResourceContext()
    steps = [
        VariantSpec(rank_ratio=0.5),
        VariantSpec(width_ratio=0.75),
        VariantSpec(width_ratio=0.5),
        VariantSpec(depth_ratio=0.75),
        VariantSpec(depth_ratio=0.5),
    ]
    cur = FULL_SPEC
    for _ in range(4):
        e = ev.evaluate(Action(variant=cur), ctx)
        if e.latency_s <= latency_budget_s:
            break
        best, best_gain = None, -1e30
        for s in steps:
            cand = VariantSpec(
                rank_ratio=min(cur.rank_ratio, s.rank_ratio),
                width_ratio=min(cur.width_ratio, s.width_ratio),
                depth_ratio=min(cur.depth_ratio, s.depth_ratio),
                ghost=cur.ghost or s.ghost,
                kv_merge=max(cur.kv_merge, s.kv_merge))
            ce = ev.evaluate(Action(variant=cand), ctx)
            gain = (e.latency_s - ce.latency_s) / max(
                e.accuracy - ce.accuracy, 1e-4)
            if gain > best_gain:
                best, best_gain = cand, gain
        cur = best
    return cur


def ofa_select(cfg: ModelConfig, shape: InputShape, latency_budget_s: float,
               candidates: Sequence[VariantSpec],
               evaluator: Optional[ActionEvaluator] = None) -> VariantSpec:
    """Once-for-all: pick the highest-accuracy subnetwork meeting the
    budget from a pre-enumerated supernet grid."""
    ev = evaluator or ActionEvaluator(cfg, shape)
    ctx = ResourceContext()
    feasible = []
    for spec in candidates:
        e = ev.evaluate(Action(variant=spec), ctx)
        if e.latency_s <= latency_budget_s:
            feasible.append((e.accuracy, spec))
    if not feasible:
        return min(candidates,
                   key=lambda s: ev.evaluate(Action(variant=s),
                                             ctx).latency_s)
    return max(feasible, key=lambda t: t[0])[1]
