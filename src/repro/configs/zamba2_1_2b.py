"""Zamba2-1.2B [hybrid]: Mamba2 blocks + ONE shared attention block
applied every 6 mamba blocks (weight recycling, per the paper's
η2-style squeeze).  [arXiv:2411.15242]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state_dim=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    shared_attn_period=6,
    gated_ffn=True, activation="gelu",
    source="arXiv:2411.15242",
)
