"""Whisper-small [audio]: enc-dec; conv/mel frontend is a STUB — the
encoder consumes precomputed frame embeddings.  [arXiv:2212.04356]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch_type="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51865,
    gated_ffn=False, activation="gelu",
    is_encoder_decoder=True, encoder_layers=12, encoder_seq_len=1500,
    source="arXiv:2212.04356",
)
