"""Yi-34B [dense]: llama-arch GQA kv=8.  [arXiv:2403.04652]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", arch_type="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64000,
    gated_ffn=True, activation="silu", rope_theta=5e6,
    max_seq_len=200000,
    source="arXiv:2403.04652",
)
