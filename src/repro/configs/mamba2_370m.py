"""Mamba2-370m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state_dim=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    inapplicable_operators=("window_scaling",),
    source="arXiv:2405.21060",
)
