"""Gemma3-12B [dense]: 5 local : 1 global attention, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", arch_type="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=15360, vocab_size=262144,
    gated_ffn=True, activation="gelu",
    local_global_ratio=5, sliding_window=1024, rope_theta=1e6,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt (scaled per assignment)",
)
