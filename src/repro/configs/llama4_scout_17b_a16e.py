"""Llama-4-Scout-17B-16E [moe]: 16 experts top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=16, experts_per_token=1, moe_shared_expert=True,
    gated_ffn=True, activation="silu", rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
