"""BONUS (beyond the assigned 10): Phi-3-mini-4k [dense] — 3.8B small
dense LLM.  [arXiv:2404.14219]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini", arch_type="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    head_dim=96, d_ff=8192, vocab_size=32064,
    gated_ffn=True, activation="silu",
    source="arXiv:2404.14219 (bonus arch)",
)
