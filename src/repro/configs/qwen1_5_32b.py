"""Qwen1.5-32B [dense]: QKV bias, MHA (kv=40).  [hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch_type="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    head_dim=128, d_ff=27392, vocab_size=152064,
    qkv_bias=True, gated_ffn=True, activation="silu",
    rope_theta=1e6, max_seq_len=32768,
    source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment)",
)
