"""Gemma-7B [dense]: GeGLU, head_dim=256.  [arXiv:2403.08295]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", arch_type="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    gated_ffn=True, activation="gelu",
    source="arXiv:2403.08295",
)
