"""InternVL2-26B [vlm]: InternViT (STUB patch embeddings) + InternLM2
backbone.  [arXiv:2404.16821]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", arch_type="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=92553,
    gated_ffn=True, activation="silu",
    vision_embed_dim=3200, num_vision_tokens=256,
    source="arXiv:2404.16821",
)
