"""The paper's own evaluation backbone, adapted: a small elastic
transformer standing in for ResNet18/VGG16 in the CrowdHMTware
experiments (mobile CNNs do not transfer to a TPU LLM substrate; the
multi-branch/early-exit + compression-operator structure does).
Used by the middleware benchmarks and examples.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="paper-backbone", arch_type="dense",
    num_layers=8, d_model=256, num_heads=8, num_kv_heads=8,
    head_dim=32, d_ff=1024, vocab_size=2048,
    gated_ffn=True, activation="silu", max_seq_len=2048,
    source="CrowdHMTware §IV (substrate-adapted)",
)
