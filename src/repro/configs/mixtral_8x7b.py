"""BONUS (beyond the assigned 10): Mixtral-8x7B [moe] — 8 experts top-2,
the canonical open MoE.  [arXiv:2401.04088]"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    num_experts=8, experts_per_token=2,
    gated_ffn=True, activation="silu", rope_theta=1e6,
    sliding_window=4096,
    source="arXiv:2401.04088 (bonus arch)",
)
