"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture (plus the paper's own evaluation backbone) is
a selectable config; reduced same-family variants for CPU smoke tests come
from ``get_config(arch_id).reduced()``.
"""
from __future__ import annotations

from repro.models.configs import ModelConfig

from . import (gemma3_12b, gemma_7b, internvl2_26b, llama4_scout_17b_a16e,
               mamba2_370m, mixtral_8x7b, olmoe_1b_7b, paper_backbone,
               phi3_mini, qwen1_5_32b, whisper_small, yi_34b, zamba2_1_2b)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen1_5_32b, yi_34b, llama4_scout_17b_a16e, mamba2_370m,
              whisper_small, olmoe_1b_7b, gemma3_12b, internvl2_26b,
              gemma_7b, zamba2_1_2b, paper_backbone, mixtral_8x7b,
              phi3_mini)
}

ASSIGNED_ARCHS = (
    "qwen1.5-32b", "yi-34b", "llama4-scout-17b-a16e", "mamba2-370m",
    "whisper-small", "olmoe-1b-7b", "gemma3-12b", "internvl2-26b",
    "gemma-7b", "zamba2-1.2b",
)

# beyond the assignment: extra pool archs proving the config system
# generalizes (NOT part of the canonical 10x4 dry-run grid)
BONUS_ARCHS = ("mixtral-8x7b", "phi3-mini")


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return list(ASSIGNED_ARCHS)
