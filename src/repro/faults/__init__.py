"""Fault injection + self-healing for the fleet (chaos layer).

``injector`` breaks things on a deterministic, seed-driven schedule;
``detector`` notices (heartbeat suspect→dead on the fleet's own wake
clock); ``recovery`` bounds what a broken offload chain may cost
before the requester degrades to a local elastic variant; ``report``
turns the resulting trace events into MTTD/MTTR numbers.  See
``docs/RESILIENCE.md`` for the taxonomy, state machine and defaults.
"""
from .detector import (ALIVE, DEAD, RECOVERED, SUSPECT, DetectorConfig,
                       HeartbeatDetector, Transition)
from .injector import (CRASH, FAULT_KINDS, FREEZE, LINK_DEGRADE,
                       LINK_KINDS, LOAD_SPIKE, OOM, PARTITION,
                       SILENT_KINDS, STRAGGLER, TELEMETRY_CORRUPT,
                       TELEMETRY_DELAY, TELEMETRY_LOSS, FaultInjector,
                       FaultSpec, TelemetryFault, random_schedule)
from .recovery import (ChainOutcome, MigrationOutcome, RetryPolicy,
                       execute_chain, plan_migration)
from .report import FaultOutcome, schedule_to_json, summarize_faults

__all__ = [
    "ALIVE", "SUSPECT", "DEAD", "RECOVERED",
    "DetectorConfig", "HeartbeatDetector", "Transition",
    "CRASH", "FREEZE", "LINK_DEGRADE", "PARTITION", "TELEMETRY_LOSS",
    "TELEMETRY_DELAY", "TELEMETRY_CORRUPT", "STRAGGLER", "LOAD_SPIKE",
    "OOM", "FAULT_KINDS", "LINK_KINDS", "SILENT_KINDS",
    "FaultSpec", "TelemetryFault", "FaultInjector", "random_schedule",
    "RetryPolicy", "ChainOutcome", "execute_chain",
    "MigrationOutcome", "plan_migration",
    "FaultOutcome", "summarize_faults", "schedule_to_json",
]
