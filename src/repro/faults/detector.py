"""Heartbeat failure detector: suspect→dead on missed wakes.

The fleet's event scheduler already *is* a heartbeat source — every
device wake is a liveness proof.  :class:`HeartbeatDetector` tracks the
time since each device's last wake against a grace period scaled to
that device's own wake cadence (its tick-envelope ceiling plus any
engine step time), so a 1 Hz phone is not declared dead on a 4 Hz
server's schedule:

* **alive → suspect** after ``suspect_after`` missed periods — the
  device is still placed, but the controller notes the silence;
* **suspect → dead** after ``dead_after`` periods — the controller
  evicts it through the same path ``drop_device`` uses (failures are
  *discovered*, not announced);
* **suspect/dead → alive** on the next heartbeat — a *flap*.  Each flap
  doubles the device's quarantine window (capped), during which the
  placer will not select it as a helper: a blinking device must prove
  stability before it hosts anyone's layers again.

The detector is deliberately fleet-agnostic — ids, periods and clock
readings in, :class:`Transition` records out — so the chaos suite can
drive the state machine directly, without a controller."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"
RECOVERED = "recovered"      # transition kind only, never a stored state


@dataclass(frozen=True)
class DetectorConfig:
    """Grace periods in multiples of each device's OWN wake period.

    ``suspect_after`` must exceed 1.0 with headroom — a healthy device
    goes exactly one period between beats, and derate can stretch a
    wake to its envelope ceiling.  ``quarantine_periods`` is the base
    readmission hold after a flap; each further flap doubles it up to
    ``flap_backoff_cap`` doublings' worth."""
    suspect_after: float = 2.5
    dead_after: float = 5.0
    quarantine_periods: float = 6.0
    flap_backoff_cap: float = 8.0

    def __post_init__(self):
        if not (1.0 < self.suspect_after < self.dead_after):
            raise ValueError(
                f"need 1 < suspect_after < dead_after, got "
                f"{self.suspect_after} / {self.dead_after}")


@dataclass(frozen=True)
class Transition:
    """One state-machine edge: who, to what, when, and how silent."""
    device_id: str
    state: str                     # SUSPECT | DEAD | RECOVERED
    at_s: float
    silent_s: float = 0.0          # time since last beat at transition
    flaps: int = 0
    quarantined_until_s: float = 0.0
    was: str = ALIVE               # state before the edge


@dataclass
class _Tracked:
    period_s: float                # this device's current wake period
    last_beat_s: float
    state: str = ALIVE
    flaps: int = 0
    quarantined_until_s: float = 0.0


class HeartbeatDetector:
    """Suspect→dead liveness tracking over explicit heartbeats."""

    def __init__(self, config: Optional[DetectorConfig] = None):
        self.cfg = config if config is not None else DetectorConfig()
        self._tracked: Dict[str, _Tracked] = {}
        # full edge history, in occurrence order (sweeps + recoveries)
        self.transitions: List[Transition] = []

    # ------------------------------------------------------- membership ----
    def track(self, device_id: str, period_s: float,
              now_s: float = 0.0) -> None:
        """Start watching a device; ``period_s`` is its expected wake
        interval (refreshed on every beat, so DVFS slowdowns stretch
        the grace window instead of tripping it)."""
        self._tracked[device_id] = _Tracked(
            period_s=max(period_s, 1e-9), last_beat_s=now_s)

    def untrack(self, device_id: str) -> None:
        """Stop watching (announced departure or trace exhaustion — an
        expected silence must not raise a false alarm)."""
        self._tracked.pop(device_id, None)

    def tracked(self) -> List[str]:
        return list(self._tracked)

    # -------------------------------------------------------- heartbeats ---
    def beat(self, device_id: str, now_s: float,
             period_s: Optional[float] = None) -> Optional[Transition]:
        """A liveness proof.  Returns a RECOVERED transition when the
        device was suspect/dead (a flap — quarantine doubles), else
        ``None``.  Unknown devices are ignored (evicted stragglers may
        still be mid-wake when the eviction lands)."""
        tr = self._tracked.get(device_id)
        if tr is None:
            return None
        if period_s is not None:
            tr.period_s = max(period_s, 1e-9)
        silent = now_s - tr.last_beat_s
        tr.last_beat_s = now_s
        if tr.state == ALIVE:
            return None
        was = tr.state
        tr.state = ALIVE
        tr.flaps += 1
        hold = (self.cfg.quarantine_periods * tr.period_s
                * min(2.0 ** (tr.flaps - 1), self.cfg.flap_backoff_cap))
        tr.quarantined_until_s = now_s + hold
        edge = Transition(device_id, RECOVERED, now_s, silent_s=silent,
                          flaps=tr.flaps,
                          quarantined_until_s=tr.quarantined_until_s,
                          was=was)
        self.transitions.append(edge)
        return edge

    def sweep(self, now_s: float) -> List[Transition]:
        """Advance every tracked device's state machine to ``now_s``.
        Returns the edges taken this sweep (a long-silent device can
        take alive→suspect and suspect→dead in one sweep — detection
        latency is then bounded by the sweep cadence, not doubled)."""
        out: List[Transition] = []
        for did, tr in self._tracked.items():
            silent = now_s - tr.last_beat_s
            if tr.state == ALIVE \
                    and silent > self.cfg.suspect_after * tr.period_s:
                tr.state = SUSPECT
                out.append(Transition(did, SUSPECT, now_s, silent_s=silent,
                                      flaps=tr.flaps, was=ALIVE))
            if tr.state == SUSPECT \
                    and silent > self.cfg.dead_after * tr.period_s:
                tr.state = DEAD
                out.append(Transition(did, DEAD, now_s, silent_s=silent,
                                      flaps=tr.flaps, was=SUSPECT))
        self.transitions.extend(out)
        return out

    # ---------------------------------------------------------- queries ----
    def state(self, device_id: str) -> str:
        tr = self._tracked.get(device_id)
        return tr.state if tr is not None else DEAD

    def flaps(self, device_id: str) -> int:
        tr = self._tracked.get(device_id)
        return tr.flaps if tr is not None else 0

    def quarantined_until(self, device_id: str) -> float:
        tr = self._tracked.get(device_id)
        return tr.quarantined_until_s if tr is not None else 0.0

    def quarantined(self, device_id: str, now_s: float) -> bool:
        return now_s < self.quarantined_until(device_id)
