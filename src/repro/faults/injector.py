"""Deterministic fault injection against a running FleetController.

A fault schedule is a list of :class:`FaultSpec` rows — what breaks,
when (on the simulated fleet clock), for how long, how badly.
:func:`random_schedule` draws one from a seed, so a chaos run is
reproducible from ``(fleet, horizon, seed)`` alone.  The
:class:`FaultInjector` arms a schedule onto a controller as clock
callbacks (the same min-heap that drives device wakes), applies each
fault when its time comes, and automatically clears time-bounded ones.

Fault taxonomy (``FAULT_KINDS``):

* ``crash`` — the device stops waking, permanently; its state machine
  must be *discovered* dead by the detector.
* ``freeze`` — stops waking for ``duration_s`` but holds state, then
  resumes (the flapping case quarantine exists for).
* ``link_degrade`` — the link between two sites loses ``magnitude``×
  bandwidth and gains ``magnitude``× RTT (``target="siteA|siteB"``).
* ``partition`` — the site pair's link collapses to ~zero bandwidth.
* ``telemetry_loss`` — the device's reports are dropped with
  probability ``magnitude``.
* ``telemetry_delay`` — reports arrive ``magnitude`` seconds late.
* ``telemetry_corrupt`` — observed latencies are scaled ``magnitude``×
  before reporting (a lying sensor).
* ``straggler`` — DVFS collapse: the device's effective derate is
  capped at ``magnitude`` (< 1), slowing wakes and raw latency.
* ``load_spike`` — hosted-load spike: the member is marked
  ``magnitude`` busy in the placer (requires placement).
* ``oom`` — the device's serving engine fails its next ``magnitude``
  admissions with an OOM (requires an attached engine).

Everything lands on the trace timeline as ``fault.inject`` /
``fault.clear`` instants, so MTTD/MTTR are measurable from the same
artifact the rest of the stack already exports.

The module deliberately imports nothing from ``repro.fleet`` at module
scope (the controller imports the detector from this package; keeping
injector → fleet references runtime-only avoids the cycle)."""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

CRASH = "crash"
FREEZE = "freeze"
LINK_DEGRADE = "link_degrade"
PARTITION = "partition"
TELEMETRY_LOSS = "telemetry_loss"
TELEMETRY_DELAY = "telemetry_delay"
TELEMETRY_CORRUPT = "telemetry_corrupt"
STRAGGLER = "straggler"
LOAD_SPIKE = "load_spike"
OOM = "oom"

FAULT_KINDS = (CRASH, FREEZE, LINK_DEGRADE, PARTITION, TELEMETRY_LOSS,
               TELEMETRY_DELAY, TELEMETRY_CORRUPT, STRAGGLER, LOAD_SPIKE,
               OOM)

# kinds whose target is a "siteA|siteB" pair rather than a device id
LINK_KINDS = (LINK_DEGRADE, PARTITION)
# kinds the heartbeat detector is expected to discover (silence faults)
SILENT_KINDS = (CRASH, FREEZE)


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``duration_s=0`` means permanent;
    ``magnitude`` is kind-specific (see module docstring)."""
    kind: str
    target: str
    at_s: float
    duration_s: float = 0.0
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    @property
    def sites(self) -> Tuple[str, str]:
        """The site pair of a link fault (``target="a|b"``)."""
        a, _, b = self.target.partition("|")
        return (a, b)


@dataclass(frozen=True)
class TelemetryFault:
    """Active telemetry corruption on one device's reporting path."""
    loss_p: float = 0.0            # drop probability per report
    delay_s: float = 0.0           # extra arrival latency per report
    corrupt_scale: float = 1.0     # observed-latency multiplier


def random_schedule(devices: Sequence, horizon_s: float, seed: int, *,
                    n_faults: int = 4,
                    kinds: Sequence[str] = (CRASH, FREEZE, STRAGGLER,
                                            TELEMETRY_LOSS, PARTITION),
                    protect: Sequence[str] = ()) -> List[FaultSpec]:
    """Draw a reproducible fault schedule for a fleet.

    ``devices`` are :class:`~repro.fleet.registry.DeviceSpec`-likes
    (``.device_id`` + ``.site`` are all that is read); ``protect``
    lists device ids never targeted (e.g. the requester a test asserts
    goodput for).  Injection times land in the middle 60% of the
    horizon so warmup calibration and the final drain stay fault-free
    enough to measure against."""
    rng = random.Random(seed)
    eligible = [d for d in devices if d.device_id not in protect]
    if not eligible:
        raise ValueError("no eligible fault targets (all protected)")
    sites = sorted({d.site for d in devices})
    out: List[FaultSpec] = []
    for _ in range(n_faults):
        kind = rng.choice(list(kinds))
        at = (0.2 + 0.6 * rng.random()) * horizon_s
        dur = (0.1 + 0.2 * rng.random()) * horizon_s
        if kind in LINK_KINDS:
            if len(sites) < 2:
                kind = FREEZE       # single-site fleet: nothing to cut
            else:
                a, b = rng.sample(sites, 2)
                mag = 8.0 + rng.random() * 8.0 \
                    if kind == LINK_DEGRADE else 1.0
                out.append(FaultSpec(kind, f"{a}|{b}", at, dur, mag))
                continue
        target = rng.choice(eligible).device_id
        if kind == CRASH:
            out.append(FaultSpec(kind, target, at, 0.0))
        elif kind == FREEZE:
            out.append(FaultSpec(kind, target, at, dur))
        elif kind == STRAGGLER:
            out.append(FaultSpec(kind, target, at, dur,
                                 magnitude=0.1 + 0.2 * rng.random()))
        elif kind == LOAD_SPIKE:
            out.append(FaultSpec(kind, target, at, dur,
                                 magnitude=0.7 + 0.25 * rng.random()))
        elif kind == TELEMETRY_LOSS:
            out.append(FaultSpec(kind, target, at, dur,
                                 magnitude=0.3 + 0.6 * rng.random()))
        elif kind == TELEMETRY_DELAY:
            out.append(FaultSpec(kind, target, at, dur,
                                 magnitude=0.5 + rng.random()))
        elif kind == TELEMETRY_CORRUPT:
            out.append(FaultSpec(kind, target, at, dur,
                                 magnitude=2.0 + 3.0 * rng.random()))
        elif kind == OOM:
            out.append(FaultSpec(kind, target, at, 0.0,
                                 magnitude=float(rng.randint(1, 3))))
    out.sort(key=lambda f: (f.at_s, f.kind, f.target))
    return out


class FaultInjector:
    """Arms a fault schedule onto a live controller's event clock.

    ``arm()`` registers every fault as a ``schedule_at`` callback;
    faults with a ``duration_s`` also register their clearing.  The
    ``applied``/``cleared`` logs record what actually fired (a fault
    targeting a device that crashed earlier is skipped, and logged as
    such in the trace)."""

    def __init__(self, controller, schedule: Sequence[FaultSpec]):
        self.ctl = controller
        self.schedule = list(schedule)
        self.applied: List[FaultSpec] = []
        self.cleared: List[FaultSpec] = []
        self.skipped: List[FaultSpec] = []
        # saved link overrides so clears restore, not reset
        self._saved_links: Dict[Tuple[str, str], Optional[object]] = {}
        self._armed = False

    def arm(self) -> "FaultInjector":
        if self._armed:
            raise RuntimeError("schedule already armed")
        self._armed = True
        for f in self.schedule:
            self.ctl.schedule_at(f.at_s, lambda f=f: self._apply(f))
            if f.duration_s > 0:
                self.ctl.schedule_at(f.at_s + f.duration_s,
                                     lambda f=f: self._clear(f))
        return self

    # ------------------------------------------------------------ events ---
    def _emit(self, name: str, f: FaultSpec, **extra) -> None:
        rec = self.ctl.recorder
        if rec.enabled:
            rec.instant(name, pid="fleet", tid="faults", cat="fleet",
                        args={"kind": f.kind, "target": f.target,
                              "magnitude": f.magnitude, **extra})

    # ------------------------------------------------------------- apply ---
    def _apply(self, f: FaultSpec) -> None:
        ctl = self.ctl
        if f.kind in LINK_KINDS:
            topo = self._topology()
            if topo is None:
                self.skipped.append(f)
                self._emit("fault.skip", f, why="no topology")
                return
            self._degrade_link(topo, f)
        elif f.kind in (CRASH, FREEZE):
            if not ctl.device_is_up(f.target):
                self.skipped.append(f)
                self._emit("fault.skip", f, why="already down")
                return
            ctl.fail_device(f.target, mode=f.kind)
        elif f.kind == STRAGGLER:
            ctl.set_derate_cap(f.target, f.magnitude)
        elif f.kind == LOAD_SPIKE:
            if getattr(ctl, "placer", None) is None:
                self.skipped.append(f)
                self._emit("fault.skip", f, why="no placement")
                return
            ctl.inject_load(f.target, f.magnitude)
        elif f.kind == TELEMETRY_LOSS:
            ctl.set_telemetry_fault(f.target,
                                    TelemetryFault(loss_p=f.magnitude))
        elif f.kind == TELEMETRY_DELAY:
            ctl.set_telemetry_fault(f.target,
                                    TelemetryFault(delay_s=f.magnitude))
        elif f.kind == TELEMETRY_CORRUPT:
            ctl.set_telemetry_fault(
                f.target, TelemetryFault(corrupt_scale=f.magnitude))
        elif f.kind == OOM:
            eng = ctl.engine_of(f.target)
            if eng is None:
                self.skipped.append(f)
                self._emit("fault.skip", f, why="no engine")
                return
            eng.inject_oom(int(f.magnitude))
        self.applied.append(f)
        self._emit("fault.inject", f, duration_s=f.duration_s)

    def _clear(self, f: FaultSpec) -> None:
        if f not in self.applied:
            return                     # never applied → nothing to clear
        ctl = self.ctl
        if f.kind in LINK_KINDS:
            topo = self._topology()
            if topo is not None:
                self._restore_link(topo, f)
        elif f.kind == FREEZE:
            ctl.thaw_device(f.target)
        elif f.kind == STRAGGLER:
            ctl.set_derate_cap(f.target, None)
        elif f.kind == LOAD_SPIKE:
            ctl.inject_load(f.target, 0.0)
        elif f.kind in (TELEMETRY_LOSS, TELEMETRY_DELAY,
                        TELEMETRY_CORRUPT):
            ctl.set_telemetry_fault(f.target, None)
        self.cleared.append(f)
        self._emit("fault.clear", f)

    # -------------------------------------------------------------- links --
    def _topology(self):
        placer = getattr(self.ctl, "placer", None)
        return placer.topology if placer is not None else None

    @staticmethod
    def _link_key(f: FaultSpec) -> Tuple[str, str]:
        a, b = f.sites
        return (a, b) if a <= b else (b, a)

    def _degrade_link(self, topo, f: FaultSpec) -> None:
        from repro.fleet.placement.topology import LinkSpec
        key = self._link_key(f)
        if key not in self._saved_links:
            self._saved_links[key] = topo.overrides.get(key)
        base = topo.link(*key)
        if f.kind == PARTITION:
            broken = LinkSpec(bandwidth_bytes_s=1.0, rtt_s=3600.0,
                              kind=base.kind)
        else:
            m = max(f.magnitude, 1.0)
            broken = LinkSpec(bandwidth_bytes_s=base.bandwidth_bytes_s / m,
                              rtt_s=base.rtt_s * m, kind=base.kind)
        topo.overrides[key] = broken
        self._schedule_resweep()

    def _restore_link(self, topo, f: FaultSpec) -> None:
        key = self._link_key(f)
        prior = self._saved_links.pop(key, None)
        if prior is None:
            topo.overrides.pop(key, None)
        else:
            topo.overrides[key] = prior
        self._schedule_resweep()

    def _schedule_resweep(self) -> None:
        """A link change is placement-relevant NOW, not at the next
        periodic sweep."""
        sched = getattr(self.ctl, "_schedule_placement", None)
        if sched is not None:
            sched(self.ctl.now_s)
