"""Recovery policy for offload chains: timeout, backoff, degradation.

When a requester's placed chain references a hop that died between
placement sweeps, the requester does not stall until the next sweep
notices — it pays a bounded price and degrades:

* each hop attempt is bounded by a **per-hop timeout** (a multiple of
  the hop's predicted latency, floored so near-zero predictions still
  get a real deadline);
* failed hops retry under **exponential backoff**, doubling from
  ``base_backoff_s`` and capped at ``max_backoff_s``, at most
  ``max_retries`` retries per hop;
* once a hop exhausts its retries the chain is abandoned and the
  requester **degrades gracefully** to a local elastic variant (the
  compressed depth/width/rank actions already in its action space) —
  the controller strips the dead fleet target and re-decides locally.

:func:`execute_chain` is a pure accounting model of that procedure —
hosts, liveness oracle and policy in, an auditable
:class:`ChainOutcome` out — so the retry arithmetic is unit-testable
without a fleet.

:func:`plan_migration` is the same idea for the paging PR's
freeze/thaw path: given the frozen requests coming off an evicted
engine and the destination's compatibility oracle, it splits them into
zero-re-prefill migrations vs re-prefill fallbacks and totals the
generated tokens the freeze blobs preserve — auditable before any
device state moves."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded-retry parameters for one offload hop.

    Worst-case added latency per dead hop is
    ``(max_retries + 1) × timeout + Σ backoff`` — finite by
    construction, which is the whole point: a lost helper costs one
    bad wake, not a wedged requester."""
    max_retries: int = 2
    base_backoff_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0
    timeout_scale: float = 3.0     # per-hop timeout = scale × predicted
    min_timeout_s: float = 0.05

    def backoff_s(self, attempt: int) -> float:
        """Backoff after the ``attempt``-th failure (0-based), capped."""
        return min(self.base_backoff_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)

    def timeout_s(self, predicted_hop_s: float) -> float:
        """Deadline for one attempt at a hop predicted to take
        ``predicted_hop_s``."""
        return max(self.timeout_scale * predicted_hop_s,
                   self.min_timeout_s)

    def worst_case_s(self, predicted_hop_s: float) -> float:
        """Upper bound on what one dead hop can cost before abandonment."""
        timeouts = (self.max_retries + 1) * self.timeout_s(predicted_hop_s)
        backoffs = sum(self.backoff_s(i) for i in range(self.max_retries))
        return timeouts + backoffs


@dataclass(frozen=True)
class ChainOutcome:
    """What executing (or failing to execute) a chain cost.

    ``penalty_s`` is the time burned on timeouts + backoff waits —
    zero on a fully-live chain; the requester's observed latency for
    the wake includes it, so telemetry sees the real cost of the
    failure."""
    ok: bool
    attempts: int                  # hop attempts made, successes included
    retries: int                   # failed attempts that were retried
    penalty_s: float
    failed_hop: Optional[str] = None


def execute_chain(hosts: Sequence[str], hop_latency_s: float,
                  alive: Callable[[str], bool],
                  policy: RetryPolicy) -> ChainOutcome:
    """Walk a placement chain hop by hop under the retry policy.

    ``hosts[0]`` is the requester itself (never attempted — local
    execution cannot time out on a link); each helper hop is attempted
    until it answers or retries are exhausted.  ``alive`` is the
    liveness oracle consulted per attempt, so a host revived between
    retries is observed."""
    attempts = retries = 0
    penalty = 0.0
    for host in hosts[1:]:
        tried = 0
        while True:
            attempts += 1
            if alive(host):
                break
            penalty += policy.timeout_s(hop_latency_s)
            if tried >= policy.max_retries:
                return ChainOutcome(False, attempts, retries, penalty,
                                    failed_hop=host)
            penalty += policy.backoff_s(tried)
            tried += 1
            retries += 1
    return ChainOutcome(True, attempts, retries, penalty)


@dataclass(frozen=True)
class MigrationOutcome:
    """What migrating an evicted engine's in-flight work will cost.

    ``migrated`` requests thaw on the destination with zero re-prefill;
    ``fallback`` requests re-admit through ordinary prefill (their
    generated suffix folds into the prompt — still zero token loss,
    but a prefill call).  ``recovered_tokens`` counts the generated
    tokens the freeze blobs carry across — the tokens a requeue-only
    recovery would have had to re-earn through re-prefill."""
    migrated: Tuple[int, ...]
    fallback: Tuple[int, ...]
    recovered_tokens: int

    @property
    def total(self) -> int:
        return len(self.migrated) + len(self.fallback)


def plan_migration(requests: Sequence,
                   can_thaw: Callable[[object], bool]) -> MigrationOutcome:
    """Split frozen requests into thaw-able migrations vs re-prefill
    fallbacks against a destination's compatibility oracle (its
    ``engine.can_thaw``).  Pure accounting — nothing moves; the fleet
    controller executes the plan it returns."""
    migrated, fallback, tokens = [], [], 0
    for r in requests:
        frozen = getattr(r, "frozen", None)
        if frozen is not None and can_thaw(frozen):
            migrated.append(r.rid)
        else:
            fallback.append(r.rid)
        tokens += len(getattr(r, "generated", ()) or ())
    return MigrationOutcome(tuple(migrated), tuple(fallback), tokens)
