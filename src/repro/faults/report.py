"""MTTD / MTTR accounting from the trace timeline.

The injector, detector and recovery path all emit onto the PR-6 trace
recorder, so resilience metrics are *derived from the same artifact*
the rest of the stack exports — no side channel to drift out of sync:

* **MTTD** (mean time to detect): ``fault.inject`` → the target's
  first ``detector.suspect`` — when the controller first knows
  something is wrong.
* **MTTR** (mean time to recover): ``fault.inject`` → the fleet is
  re-planned around the loss — the first ``placement.decide`` after
  the eviction (or the eviction itself when placement is off, since
  eviction synchronously falls affected requesters back to local).

Only *silence* faults (crash/freeze) have a detection story; the other
kinds degrade service without killing the heartbeat and are scored by
the benchmark's goodput ratio instead.

Since the paging PR the summary also audits **live migration**: every
``req.migrate`` instant is folded into a ``migrations`` list, and
``migrated_reprefills`` counts migrated requests that nevertheless
showed up in a later ``engine.prefill`` — the zero-re-prefill claim,
checked against the same trace artifact.

The SLO tracker also lands on this timeline: ``slo_burns`` counts
``slo.burn`` window instants and ``slo_pages`` counts ``slo.page``
engagement edges, so a chaos report shows whether the injected faults
actually burned the error budget."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .injector import SILENT_KINDS, FaultSpec


def _ts(e) -> float:
    return e.sim_s if e.sim_s is not None else e.wall_s


@dataclass(frozen=True)
class FaultOutcome:
    """One injected fault's detection/recovery timeline (``None`` stamps
    mean the stage never happened inside the observed window)."""
    kind: str
    target: str
    injected_s: float
    suspected_s: Optional[float] = None
    dead_s: Optional[float] = None
    evicted_s: Optional[float] = None
    recovered_s: Optional[float] = None

    @property
    def mttd_s(self) -> Optional[float]:
        return (None if self.suspected_s is None
                else self.suspected_s - self.injected_s)

    @property
    def mttr_s(self) -> Optional[float]:
        return (None if self.recovered_s is None
                else self.recovered_s - self.injected_s)

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "target": self.target,
                "injected_s": self.injected_s,
                "suspected_s": self.suspected_s, "dead_s": self.dead_s,
                "evicted_s": self.evicted_s,
                "recovered_s": self.recovered_s,
                "mttd_s": self.mttd_s, "mttr_s": self.mttr_s}


def summarize_faults(events: Sequence) -> Dict:
    """Fold a recorder's event list into per-fault outcomes + rollups.

    ``events`` is ``TraceRecorder.events`` (or any sequence of objects
    with ``name``/``args``/``sim_s``/``wall_s``).  Returns a dict ready
    for JSON: ``outcomes`` rows plus aggregate mean/max MTTD and MTTR
    over the silence faults that were detected."""
    injects: List = []
    suspects: Dict[str, List[float]] = {}
    deads: Dict[str, List[float]] = {}
    evicts: Dict[str, List[float]] = {}
    decides: List[float] = []
    migrates: List[Dict] = []
    prefills: List = []     # (ts, rids) of every engine.prefill begin
    slo_burns = 0
    slo_pages = 0
    for e in events:
        args = e.args or {}
        if e.name == "fault.inject":
            injects.append(e)
        elif e.name == "detector.suspect":
            suspects.setdefault(args.get("device"), []).append(_ts(e))
        elif e.name == "detector.dead":
            deads.setdefault(args.get("device"), []).append(_ts(e))
        elif e.name == "fleet.evict":
            evicts.setdefault(args.get("device"), []).append(_ts(e))
        elif e.name == "placement.decide":
            decides.append(_ts(e))
        elif e.name == "req.migrate":
            migrates.append({"rid": args.get("rid"),
                             "src": args.get("src"),
                             "dst": args.get("dst"),
                             "reprefill": bool(args.get("reprefill")),
                             "ts_s": _ts(e)})
        elif e.name == "engine.prefill" and getattr(e, "ph", "B") == "B":
            prefills.append((_ts(e), args.get("rids") or []))
        elif e.name == "slo.burn":
            slo_burns += 1
        elif e.name == "slo.page":
            slo_pages += 1

    def first_after(times: Optional[List[float]], t0: float
                    ) -> Optional[float]:
        if not times:
            return None
        later = [t for t in times if t >= t0]
        return min(later) if later else None

    outcomes: List[FaultOutcome] = []
    for e in injects:
        args = e.args or {}
        kind, target, t0 = args.get("kind"), args.get("target"), _ts(e)
        if kind not in SILENT_KINDS:
            outcomes.append(FaultOutcome(kind, target, t0))
            continue
        sus = first_after(suspects.get(target), t0)
        ded = first_after(deads.get(target), t0)
        evi = first_after(evicts.get(target), t0)
        rec = first_after(decides, evi) if evi is not None else None
        outcomes.append(FaultOutcome(
            kind, target, t0, suspected_s=sus, dead_s=ded,
            evicted_s=evi, recovered_s=rec if rec is not None else evi))

    # the zero-re-prefill audit: a migrated rid re-entering any
    # engine.prefill *after* its migration means the thaw fell back
    reprefilled = 0
    for m in migrates:
        hit = any(ts >= m["ts_s"] and m["rid"] in rids
                  for ts, rids in prefills)
        m["reprefill"] = m["reprefill"] or hit
        reprefilled += int(m["reprefill"])

    mttds = [o.mttd_s for o in outcomes if o.mttd_s is not None]
    mttrs = [o.mttr_s for o in outcomes if o.mttr_s is not None]
    silent = [o for o in outcomes if o.kind in SILENT_KINDS]
    return {
        "outcomes": [o.to_dict() for o in outcomes],
        "faults": len(outcomes),
        "silent_faults": len(silent),
        "detected": len(mttds),
        "mean_mttd_s": sum(mttds) / len(mttds) if mttds else None,
        "max_mttd_s": max(mttds) if mttds else None,
        "mean_mttr_s": sum(mttrs) / len(mttrs) if mttrs else None,
        "max_mttr_s": max(mttrs) if mttrs else None,
        "migrations": migrates,
        "migrated_requests": len(migrates),
        "migrated_reprefills": reprefilled,
        "slo_burns": slo_burns,
        "slo_pages": slo_pages,
    }


def schedule_to_json(schedule: Sequence[FaultSpec]) -> List[Dict]:
    """Serialize a schedule for the benchmark artifact."""
    return [{"kind": f.kind, "target": f.target, "at_s": f.at_s,
             "duration_s": f.duration_s, "magnitude": f.magnitude}
            for f in schedule]
