"""Logical-axis sharding rules (MaxText-style) for every parameter, cache
and batch tensor, for both mesh topologies.

Weight matmul dims shard on the FUSED projection axes (q_dim, kv_dim,
d_ff, packed mamba in_proj) which every assigned architecture keeps
divisible by the 16-way model axis — head-count axes (40, 56, 8 heads…)
are NOT divisible, so activations keep heads unsharded at the jit
boundary and GSPMD propagates internal shardings from the weights.
Weights additionally FSDP over "data"; the "pod" axis is pure DP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.configs import InputShape, ModelConfig

from .mesh import batch_axes

Params = Any

FSDP = "data"
TP = "model"


def _right_align(spec: Tuple, ndim: int) -> P:
    """Pad a trailing-dims spec with leading Nones (stacked-layer dims)."""
    pad = ndim - len(spec)
    return P(*([None] * pad + list(spec)))


_REPLICATED = ("ln", "ln1", "ln2", "ln_cross", "final_norm", "encoder_norm",
               "norm_scale", "a_log", "d_skip", "dt_bias", "norms")


def leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
              cfg: ModelConfig, mode: str = "train") -> P:
    """mode="serve": weights replicate over the FSDP axis (no per-layer
    all-gathers at decode; TP/EP shards alone must fit HBM — they do for
    every assigned arch in bf16)."""
    name = path[-1]
    nd = len(shape)
    in_moe = "moe" in path
    if name in _REPLICATED or nd == 0:
        return P()
    if name == "embed":
        return P(TP, FSDP)
    if name in ("wq", "wk", "wv"):
        return _right_align((FSDP, TP), nd)
    if name == "wo":
        return _right_align((TP, FSDP), nd)
    if name in ("bq", "bk", "bv"):
        return _right_align((TP,), nd)
    if name in ("w_gate", "w_up"):
        if in_moe and nd >= 3 and shape[-3] == cfg.num_experts:
            if cfg.num_experts % 16 == 0:
                return _right_align((TP, None, None), nd)  # expert parallel
            return _right_align((None, None, TP), nd)      # E<16: TP on d_ff
        return _right_align((FSDP, TP), nd)
    if name == "w_down":
        if in_moe and nd >= 3 and shape[-3] == cfg.num_experts:
            if cfg.num_experts % 16 == 0:
                return _right_align((TP, None, None), nd)
            return _right_align((None, TP, None), nd)
        return _right_align((TP, FSDP), nd)
    if name == "router":
        return _right_align((FSDP, None), nd)
    if name == "in_proj":
        return _right_align((FSDP, TP), nd)
    if name == "out_proj":
        return _right_align((TP, FSDP), nd)
    if name == "conv_w":
        return _right_align((TP, None), nd)
    if name == "conv_b":
        return _right_align((TP,), nd)
    if name == "w" and "vision_proj" in path:
        return P(FSDP, None)
    return P()  # safe default: replicate


def _path_names(kp) -> Tuple[str, ...]:
    out = []
    for k in kp:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return tuple(out)


def param_specs(cfg: ModelConfig, params_shape: Params,
                mode: str = "train") -> Params:
    """PartitionSpec tree matching an eval_shape'd param tree."""
    def spec(kp, leaf):
        s = leaf_spec(_path_names(kp), leaf.shape, cfg)
        if mode == "serve":
            s = P(*[None if ax == FSDP else ax for ax in s])
        return s
    return jax.tree_util.tree_map_with_path(spec, params_shape)


def opt_state_specs(cfg: ModelConfig, opt_shape, pspecs) -> Any:
    """AdamW m/v mirror the parameter specs; step is replicated."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), m=pspecs, v=pspecs)


# ------------------------------------------------------------- caches ------
def _tp_axis_for(dim: int, mesh) -> Optional[str]:
    size = mesh.shape.get(TP, 1)
    return TP if dim % size == 0 else None


def cache_specs(cfg: ModelConfig, cache_shape: Dict[str, Any], mesh,
                shape: InputShape, kv_shard: str = "heads") -> Dict[str, Any]:
    """KV/SSM cache shardings.

    decode_32k: batch -> (pod,)data, kv heads -> model when divisible,
                else head_dim -> model.
    long_500k (batch=1): cache *sequence* -> (pod+)data (context
                parallelism), heads as above."""
    b_axes = batch_axes(mesh)
    specs: Dict[str, Any] = {}
    total = 1
    for a in b_axes:
        total *= mesh.shape[a]
    batch_shardable = (shape.global_batch % total == 0
                       and shape.global_batch >= total)
    seq_parallel = not batch_shardable
    for key, leaf in cache_shape.items():
        nd = len(leaf.shape)
        if key == "pos":
            specs[key] = P()
        elif key in ("k", "v", "shared_k", "shared_v", "cross_k", "cross_v"):
            # (L, B, S, K, hd)
            kdim, hdim = leaf.shape[3], leaf.shape[4]
            kv_ax = _tp_axis_for(kdim, mesh)
            hd_ax = _tp_axis_for(hdim, mesh) if kv_ax is None else None
            if kv_shard == "seq" and key not in ("cross_k", "cross_v") \
                    and not seq_parallel:
                # §Perf: split-KV (flash-decoding style) — the cache SEQ dim
                # shards over "model"; attention reduces over seq shards via
                # small softmax-stat collectives instead of gathering KV
                specs[key] = P(None, b_axes, TP, None, None)
                continue
            if seq_parallel and key not in ("cross_k", "cross_v"):
                specs[key] = P(None, None, b_axes, kv_ax, hd_ax)
            elif seq_parallel:
                # cross-attn cache: fixed encoder length, unshardable batch
                specs[key] = P(None, None, None, kv_ax, hd_ax)
            else:
                specs[key] = P(None, b_axes, None, kv_ax, hd_ax)
        elif key == "ssm":
            # (L, B, H, P, N)
            h_ax = _tp_axis_for(leaf.shape[2], mesh)
            specs[key] = P(None, None if seq_parallel else b_axes, h_ax,
                           None, None)
        elif key == "conv":
            # (L, B, W-1, C)
            c_ax = _tp_axis_for(leaf.shape[3], mesh)
            specs[key] = P(None, None if seq_parallel else b_axes, None, c_ax)
        else:
            specs[key] = P()
    return specs


# -------------------------------------------------------------- batches ----
def batch_specs(cfg: ModelConfig, mesh, shape: InputShape,
                decode: bool = False) -> Dict[str, P]:
    b_axes = batch_axes(mesh)
    total = 1
    for a in b_axes:
        total *= mesh.shape[a]
    b_spec = b_axes if shape.global_batch % total == 0 and \
        shape.global_batch >= total else None
    out: Dict[str, P] = {}
    if decode:
        out["token"] = P(b_spec)
    else:
        out["tokens"] = P(b_spec, None)
        out["labels"] = P(b_spec, None)
    if cfg.is_encoder_decoder:
        out["encoder_frames"] = P(b_spec, None, None)
    if cfg.vision_embed_dim:
        out["vision_embeds"] = P(b_spec, None, None)
    return out


def to_shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree_specs, is_leaf=lambda x: isinstance(x, P))
