"""Adaptive serving driver: batched requests through the ServingEngine
with the CrowdHMTware loop swapping variants as the context trace evolves.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Budgets, Middleware, ResourceContext, case_study_trace
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-backbone")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--adapt-every", type=int, default=8)
    ap.add_argument("--decode-mode", default="batched",
                    choices=["batched", "per_slot"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    shape = InputShape("serve", args.max_seq, args.slots, "decode")
    mw = Middleware(cfg=cfg, params=params, shape=shape,
                    budgets=Budgets(latency_s=1.0, memory_bytes=8e9),
                    allow_offload=False)
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_seq=args.max_seq,
                           decode_mode=args.decode_mode)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(8, 48)).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=12))

    trace = list(case_study_trace(max(args.requests // args.adapt_every, 2)))
    ti = 0
    t0 = time.time()
    step = 0
    while any(engine._active) or engine._queue:
        engine.step()
        step += 1
        if step % args.adapt_every == 0 and ti < len(trace):
            d = mw.adapt(trace[ti])
            ti += 1
            vcfg, vparams, vopts = mw.current_runtime()
            if vcfg != engine.cfg or vopts != engine.opts:
                print(f"[adapt] {d.reason}: {d.action.describe()[:80]}")
                engine.swap_model(vcfg, vparams, vopts)
    dt = time.time() - t0
    s = engine.stats
    print(f"served {args.requests} requests in {dt:.1f}s — "
          f"{s.steps} steps, {s.tokens_out} tokens "
          f"({s.tokens_per_step:.2f} tok/step), {s.prefills} prefills, "
          f"{s.recompiles} recompiles, {engine.generation} variant swaps")
    print(mw.report())


if __name__ == "__main__":
    main()
