from .mesh import batch_axes, make_debug_mesh, make_production_mesh
from .sharding import (batch_specs, cache_specs, opt_state_specs,
                       param_specs, to_shardings)
from .steps import (cache_spec_struct, input_specs, make_prefill_step,
                    make_serve_step, make_step, make_train_step, options_for,
                    params_spec_struct)

__all__ = ["batch_axes", "make_debug_mesh", "make_production_mesh",
           "batch_specs", "cache_specs", "opt_state_specs", "param_specs",
           "to_shardings", "cache_spec_struct", "input_specs",
           "make_prefill_step", "make_serve_step", "make_step",
           "make_train_step", "options_for", "params_spec_struct"]
