import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function against ShapeDtypeStruct inputs with the
production shardings — no allocation, no execution — and records
memory_analysis / cost_analysis / collective bytes for the roofline
report (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core.profiler import (TPU_V5E, analytic_step_costs,
                                 collective_bytes_from_hlo,
                                 collective_bytes_scan_corrected,
                                 model_flops_estimate, roofline_terms,
                                 scan_trip_count)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_specs, cache_specs, opt_state_specs,
                                   param_specs, to_shardings)
from repro.launch.steps import (cache_spec_struct, input_specs, make_step,
                                options_for, params_spec_struct)
from repro.models.configs import INPUT_SHAPES
from repro.optim import adamw

from jax.sharding import PartitionSpec as P


def build_args(cfg, shape, mesh, opts, param_mode: str = "train",
               kv_shard: str = "heads"):
    """(arg structs, arg shardings, out shardings, donate) for the step."""
    pstruct = params_spec_struct(cfg)
    pspecs = param_specs(cfg, pstruct, mode=param_mode)
    bstruct = input_specs(cfg, shape, opts)
    bspecs = batch_specs(cfg, mesh, shape, decode=shape.is_decode)
    bspecs = {k: bspecs.get(k, P()) for k in bstruct}
    if shape.kind == "train":
        ostruct = jax.eval_shape(adamw.init, pstruct)
        ospecs = opt_state_specs(cfg, ostruct, pspecs)
        structs = (pstruct, ostruct, bstruct)
        in_specs = (pspecs, ospecs, bspecs)
        out_specs = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
        donate = (0, 1)
    else:
        cstruct = cache_spec_struct(cfg, shape, opts)
        cspecs = cache_specs(cfg, cstruct, mesh, shape, kv_shard=kv_shard)
        structs = (pstruct, cstruct, bstruct)
        in_specs = (pspecs, cspecs, bspecs)
        if shape.is_decode:
            logits_spec = P(bspecs["token"][0], "model")
        else:
            logits_spec = P(bspecs["tokens"][0], None, "model")
        out_specs = (logits_spec, cspecs)
        donate = (1,)
    return structs, in_specs, out_specs, donate


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Path, verbose: bool = True,
            opt_overrides: dict | None = None, param_mode: str = "train",
            tag: str = "", param_dtype: str = "", kv_shard: str = "heads"
            ) -> dict:
    cfg = get_config(arch)
    if param_dtype:
        cfg = cfg.with_updates(param_dtype=param_dtype)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    opts = options_for(cfg, shape, opt_overrides)
    step = make_step(cfg, shape, opts)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "kind": shape.kind, "status": "ok", "param_mode": param_mode,
           "tag": tag, "opt_overrides": opt_overrides or {}}
    t0 = time.time()
    try:
        structs, in_specs, out_specs, donate = build_args(
            cfg, shape, mesh, opts, param_mode=param_mode,
            kv_shard=kv_shard)
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=to_shardings(in_specs, mesh),
                             out_shardings=to_shardings(out_specs, mesh),
                             donate_argnums=donate)
            lowered = jitted.lower(*structs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)[:200]}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_analysis"] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed",
                                               ca.get("bytes_accessed", 0.0))),
            }
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)[:200]}

        trips = scan_trip_count(cfg)
        try:
            hlo = compiled.as_text()
            coll_raw = collective_bytes_from_hlo(hlo)
            coll = collective_bytes_scan_corrected(hlo, trips)
            rec["collective_bytes_raw"] = coll_raw
            rec["collective_bytes"] = coll
            rec["collective_total"] = float(sum(coll.values()))
            rec["hlo_lines"] = hlo.count("\n")
        except Exception as e:
            rec["collective_bytes"] = {"error": str(e)[:200]}
            rec["collective_total"] = 0.0

        # Roofline terms.  XLA CPU cost_analysis counts while bodies ONCE
        # (verified empirically), so the compute/memory terms come from the
        # scan-exact analytic model; collectives come from the compiled HLO
        # with while-body trip correction.  Raw HLO numbers are recorded
        # alongside for reference.
        kv_b = 1 if opts.kv_cache_dtype == "fp8" else 2
        a_flops, a_bytes = analytic_step_costs(
            cfg, shape, remat=opts.remat, kv_bytes=kv_b,
            decode_window=opts.decode_window)
        coll_b = rec.get("collective_total", 0.0)
        mflops = model_flops_estimate(cfg, shape)
        rt = roofline_terms(hlo_flops=a_flops, hlo_bytes=a_bytes,
                            collective_bytes=coll_b * chips, chips=chips,
                            model_flops=mflops, hw=TPU_V5E)
        rec["analytic"] = {"flops": a_flops, "bytes": a_bytes,
                           "scan_trips": trips}
        rec["roofline"] = {
            "compute_s": rt.compute_s, "memory_s": rt.memory_s,
            "collective_s": rt.collective_s, "dominant": rt.dominant,
            "model_flops": mflops,
            "useful_compute_ratio": rt.useful_compute_ratio,
        }
    except Exception as e:
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = out_dir / (f"{arch.replace('.', '_')}__{shape_name}"
                    f"__{rec['mesh']}{suffix}.json")
    fn.write_text(json.dumps(rec, indent=2, default=str))
    if verbose:
        r = rec.get("roofline", {})
        print(f"[{rec['status']}] {arch} × {shape_name} × {rec['mesh']}  "
              f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
              f"dominant={r.get('dominant')} "
              f"terms=({r.get('compute_s', 0):.3e},{r.get('memory_s', 0):.3e},"
              f"{r.get('collective_s', 0):.3e})s", flush=True)
        if rec["status"] == "FAIL":
            print(rec["error"], flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--param-mode", default="train",
                    choices=["train", "serve"])
    ap.add_argument("--param-dtype", default="")
    ap.add_argument("--kv-shard", default="heads",
                    choices=["heads", "seq"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="RuntimeOptions override key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out = Path(args.out)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, out,
                              opt_overrides=overrides or None,
                              param_mode=args.param_mode, tag=args.tag,
                              param_dtype=args.param_dtype,
                              kv_shard=args.kv_shard)
                failures += rec["status"] != "ok"
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
