"""End-to-end training driver.

Runs real steps on whatever devices exist (CPU debug mesh by default; the
production mesh when chips are available), with the middleware adaptation
loop optionally in control of remat/sub-batching as memory budgets change.

Example (the examples/train_e2e.py driver uses this):
  PYTHONPATH=src python -m repro.launch.train --arch paper-backbone \
      --steps 200 --batch 8 --seq 256 --d-model 512 --layers 12
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLM, DataConfig
from repro.models.configs import InputShape, ModelConfig
from repro.models.model import init_params
from repro.optim import adamw

from .mesh import make_debug_mesh
from .steps import make_train_step, options_for


def train_loop(cfg: ModelConfig, shape: InputShape, steps: int,
               seed: int = 0, log_every: int = 10,
               remat: str = "none",
               checkpoint_dir: Optional[str] = None,
               callback=None) -> dict:
    opts = options_for(cfg, shape, {"remat": remat})
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, opts), donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=shape.seq_len,
                                  batch_size=shape.global_batch, seed=seed))
    losses = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            losses.append((i, loss))
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{(time.time() - t0) / (i + 1):.2f}s/step", flush=True)
        if callback is not None:
            params, opt_state = callback(i, params, opt_state, metrics)
    if checkpoint_dir:
        save_checkpoint(f"{checkpoint_dir}/step_{steps:06d}", params,
                        step=steps, metadata={"arch": cfg.name})
    return {"losses": losses, "params": params,
            "seconds": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-backbone")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    kw = {}
    if args.layers:
        kw["num_layers"] = args.layers
    if args.d_model:
        kw["d_model"] = args.d_model
        kw["head_dim"] = 0
    if kw:
        cfg = cfg.with_updates(**kw)
    shape = InputShape("cli", args.seq, args.batch, "train")
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    out = train_loop(cfg, shape, args.steps, remat=args.remat,
                     checkpoint_dir=args.checkpoint_dir or None)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} in {out['seconds']:.0f}s")


if __name__ == "__main__":
    main()
