"""Jit-able train / prefill / serve steps + ShapeDtypeStruct input specs.

These are the four functions the dry-run lowers for every (architecture ×
input shape × mesh) combination, and the same functions the real train.py
/ serve.py drivers execute on CPU-scale configs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import InputShape, ModelConfig
from repro.models.layers import Params
from repro.models.model import (decode_step, forward, init_cache,
                                init_params, lm_loss, prefill)
from repro.models.runtime import RuntimeOptions
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


def options_for(cfg: ModelConfig, shape: InputShape,
                overrides: Optional[Dict[str, Any]] = None) -> RuntimeOptions:
    """Engine defaults per workload (the middleware's θ_s baseline)."""
    kw: Dict[str, Any] = {}
    if shape.kind == "train":
        kw.update(remat="full", attn_impl="auto", q_chunk=512, k_chunk=1024)
    elif shape.kind == "prefill":
        kw.update(remat="none", attn_impl="auto", q_chunk=512, k_chunk=1024)
    else:  # decode
        kw.update(remat="none")
        if shape.seq_len > 100_000:
            # long_500k: sub-quadratic decode — engine-selected sliding
            # window (SSM/hybrid are O(1) anyway; their shared/local
            # attention blocks adopt the same window)
            kw.update(decode_window=8192)
    kw.update(overrides or {})
    return RuntimeOptions(**kw)


# ------------------------------------------------------------ input specs --
def input_specs(cfg: ModelConfig, shape: InputShape,
                opts: Optional[RuntimeOptions] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
    shardable, no device allocation."""
    opts = opts or options_for(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        specs["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.vision_embed_dim and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_vision_tokens, cfg.vision_embed_dim), jnp.bfloat16)
    return specs


def cache_spec_struct(cfg: ModelConfig, shape: InputShape,
                      opts: RuntimeOptions) -> Dict[str, Any]:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, opts))


def params_spec_struct(cfg: ModelConfig) -> Params:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ------------------------------------------------------------- the steps ---
def make_train_step(cfg: ModelConfig, opts: RuntimeOptions,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()
                    ) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = forward(
                p, cfg, batch["tokens"], opts,
                encoder_frames=batch.get("encoder_frames"),
                vision_embeds=batch.get("vision_embeds"))
            return (lm_loss(logits, batch["labels"])
                    + cfg.router_aux_weight * aux)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = warmup_cosine(opt_state.step)
        new_params, new_state = adamw.apply(grads, params, opt_state,
                                            opt_cfg, lr_scale=lr)
        metrics = {"loss": loss, "grad_norm": adamw.global_norm(grads)}
        return new_params, new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, opts: RuntimeOptions) -> Callable:
    def prefill_step(params, cache, batch):
        logits, cache = prefill(
            params, cfg, batch["tokens"], cache, opts,
            encoder_frames=batch.get("encoder_frames"),
            vision_embeds=batch.get("vision_embeds"))
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, opts: RuntimeOptions) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = decode_step(params, cfg, cache, batch["token"], opts)
        return logits, cache
    return serve_step


def make_step(cfg: ModelConfig, shape: InputShape,
              opts: Optional[RuntimeOptions] = None) -> Callable:
    opts = opts or options_for(cfg, shape)
    if shape.kind == "train":
        return make_train_step(cfg, opts)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, opts)
    return make_serve_step(cfg, opts)
