"""Production mesh builders.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across the ICI-disconnected pods
(DCN), "data" carries FSDP, "model" carries tensor/expert parallelism.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before anything else).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on 1-8 CPUs)."""
    devices = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
