from .adamw import AdamWConfig, AdamWState, apply, global_norm, init
from .schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "AdamWState", "apply", "global_norm", "init",
           "constant", "warmup_cosine"]
