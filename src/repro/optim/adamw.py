"""AdamW with sharding-transparent state (m/v mirror the param shardings)
plus the engine's layerwise-immediate update mode (paper §III-C2 ❹:
backprop operator reordering — gradients are consumed right after their
layer's update instead of being held for a global step)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def init(params: Params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32) \
        if jnp.issubdtype(p.dtype, jnp.floating) else jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(g.dtype, jnp.floating)]
    return jnp.sqrt(sum(leaves))


def apply(grads: Params, params: Params, state: AdamWState,
          cfg: AdamWConfig = AdamWConfig(),
          lr_scale: jax.Array | float = 1.0) -> Tuple[Params, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, m, v
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
