"""Elastic-inference showcase: the six compression-operator families on one
backbone — derivation, cost, fidelity, early exits and ensemble training.

  PYTHONPATH=src python examples/elastic_showcase.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.elastic import (FULL_SPEC, NAMED_COMBOS, ElasticSupernet,
                           attach_exits, early_exit_predict, ensemble_loss,
                           sample_variant_specs)
from repro.models import forward, init_params


def main():
    cfg = get_config("paper-backbone")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    sn = ElasticSupernet(cfg, params)
    base, _ = forward(params, cfg, tokens)
    base_flops = sn.cost(FULL_SPEC)["flops_per_token"]

    print(f"backbone {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"applicable operators: {sn.applicable_operators()}")
    print(f"{'combo':12s} {'flops':>7s} {'params':>8s} {'TV drift':>9s}")
    for name, spec in NAMED_COMBOS.items():
        vcfg, vp = sn.variant(spec)
        lg, _ = forward(vp, vcfg, tokens)
        p = jax.nn.softmax(base.astype(jnp.float32), -1)
        q = jax.nn.softmax(lg.astype(jnp.float32), -1)
        tv = float(0.5 * jnp.abs(p - q).sum(-1).mean())
        n = sum(x.size for x in jax.tree_util.tree_leaves(vp))
        ratio = sn.cost(spec)["flops_per_token"] / base_flops
        print(f"{name:12s} {ratio:6.0%} {n/1e6:7.1f}M {tv:9.3f}")

    # early exits: attach heads at depths 2 and 5, sweep the threshold
    p2 = attach_exits(cfg, params, key, positions=(2, 5))
    print("\nearly-exit depth distribution by confidence threshold:")
    # random-init logits are near-uniform over 2048 tokens, so
    # meaningful thresholds sit near 1/V
    for thr in (0.9, 0.001, 0.0):
        _, depth = early_exit_predict(p2, cfg, tokens, threshold=thr)
        counts = jnp.bincount(depth.flatten(), length=3)
        print(f"  thr={thr:4.2f}: exits@[2,5,final] = {list(map(int, counts))}")

    # one ensemble (sandwich-rule) training step through recycled weights
    labels = jnp.roll(tokens, -1, 1)
    specs = sample_variant_specs(key, 2)
    loss, grads = jax.value_and_grad(
        lambda p: ensemble_loss(p, cfg, tokens, labels, key, specs))(params)
    gn = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
             for g in jax.tree_util.tree_leaves(grads))
    print(f"\nensemble step over variants {[s.operators() for s in specs]}: "
          f"loss={float(loss):.3f}, |grad|_1={gn:.1f} "
          f"(gradients flow into the shared backbone tensors)")


if __name__ == "__main__":
    main()
