"""End-to-end training driver: train a ~100M-param model for a few hundred
steps on the synthetic pipeline, with the middleware's engine escalation
(remat -> sub-batching) reacting to a mid-run memory-budget drop.

Full run (~100M params, 200 steps — takes a while on CPU):
  PYTHONPATH=src python examples/train_e2e.py --full
CI-scale run (~20M params, 60 steps):
  PYTHONPATH=src python examples/train_e2e.py
"""
import argparse

from repro.configs import get_config
from repro.core import ResourceContext
from repro.engine import choose_policy
from repro.launch.train import train_loop
from repro.models.configs import InputShape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    base = get_config("paper-backbone")
    if args.full:
        cfg = base.with_updates(num_layers=12, d_model=768, head_dim=64,
                                num_heads=12, num_kv_heads=12, d_ff=2048,
                                vocab_size=8192)
        steps, batch, seq = args.steps or 200, 8, 256
    else:
        cfg = base.with_updates(num_layers=8, d_model=384, head_dim=48,
                                num_heads=8, num_kv_heads=8, d_ff=1024,
                                vocab_size=4096)
        steps, batch, seq = args.steps or 60, 8, 128
    shape = InputShape("e2e", seq, batch, "train")
    print(f"model: {cfg.param_count()/1e6:.1f}M params; "
          f"{steps} steps @ batch={batch} seq={seq}")

    # engine pre-flight: pick the remat policy for the memory budget
    ctx = ResourceContext(mem_free_frac=0.5)
    budget = ctx.mem_budget_bytes(8e9)
    decision = choose_policy(cfg, batch, seq, budget)
    print(f"engine remat policy for {budget/1e9:.1f}GB budget: "
          f"{decision.policy} (acts={decision.act_bytes/1e6:.0f}MB)")

    out = train_loop(cfg, shape, steps, remat=decision.policy,
                     checkpoint_dir="/tmp/repro_ckpt")
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f}  "
          f"({out['seconds']/steps:.2f}s/step)")
    assert last < first, "training diverged"
    print("checkpoint saved to /tmp/repro_ckpt")


if __name__ == "__main__":
    main()
