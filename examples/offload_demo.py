"""Scalable offloading demo: pre-partition a model once, then re-place it
across three different device pools as contexts change — partitioning is
decoupled from the placement search (paper §III-B).

  PYTHONPATH=src python examples/offload_demo.py
"""
from repro.configs import get_config
from repro.offload import (DEVICE_POOLS, build_model_graph, local_only,
                           place_cas, place_dads, place_dp, pre_partition)


def main():
    cfg = get_config("paper-backbone")
    g = build_model_graph(cfg, batch=1, seq=256)
    print(f"IR: {len(g.nodes)} ops, {g.total_flops()/1e9:.2f} GFLOPs, "
          f"{g.total_param_bytes()/1e6:.1f} MB params")

    pp = pre_partition(g)
    for lvl, name in enumerate(["operator", "sublayer-flow", "layer",
                                "coarse-stage"]):
        print(f"  granularity L{lvl} ({name}): {len(pp.units(lvl))} units")

    for pool in ("edge_pair", "edge_trio", "pod_pipeline"):
        devs = DEVICE_POOLS[pool]
        base = local_only(pp, devs)
        pl = place_dp(pp, devs)
        print(f"\npool={pool}: local={base.latency_s*1e3:.2f}ms -> "
              f"placed={pl.latency_s*1e3:.3f}ms "
              f"({base.latency_s/pl.latency_s:.1f}x), "
              f"transfer={pl.transfer_s*1e3:.2f}ms")
        print("  " + pl.describe(pp.units(pl.level), devs))
        cas = place_cas(pp, devs)
        dads = place_dads(pp, devs)
        print(f"  baselines: CAS={cas.latency_s*1e3:.2f}ms "
              f"DADS={dads.latency_s*1e3:.2f}ms")


if __name__ == "__main__":
    main()
