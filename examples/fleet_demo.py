"""Fleet demo: a heterogeneous crowd of devices co-adapting together.

Builds a small fleet spanning all three hardware tiers and runs it
**event-driven**: each device wakes at its own envelope rate (a TPU
slice re-adapts 4× as often as a little-core phone), telemetry reports
arrive out of order, and tier-pooled calibration closes the paper's
back-end→front-end feedback loop.  One device is backed by a REAL
ServingEngine on a tiny model — its measured decode-step wall-times
(not simulated silicon) are what telemetry sees for that device, and
its step-time EWMA stretches the device's wake period.

The whole run is traced: a :class:`TraceRecorder` collects spans from
all four layers (request lifecycle, engine steps, fleet clock events,
placement decisions) on the shared simulated-clock timebase and writes
``trace.json`` — open it at https://ui.perfetto.dev ("Open trace file")
or ``chrome://tracing`` to see the cross-level loop as one timeline.

  PYTHONPATH=src python examples/fleet_demo.py
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.fleet import FleetController, build_fleet, fleet_report
from repro.models.configs import InputShape
from repro.models.model import init_params
from repro.obs import LAYERS, TraceRecorder, write_trace
from repro.serving import Request


def main() -> None:
    cfg = get_config("paper-backbone").with_updates(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512)
    shape = InputShape("fleet_demo", 128, 2, "decode")
    # 5 devices interleaved over tiers → exactly one light-tier device,
    # which we back with the real engine (so its tier pool holds only
    # real measurements, not a mix of real and simulated silicon)
    fleet = build_fleet(5, seed=0)
    print("fleet:")
    for d in fleet:
        print(f"  {d.device_id:24s} tier={d.tier:6s} "
              f"peak={d.hw.peak_flops/1e12:.2f} TFLOP/s "
              f"wake_every={d.tick_envelope.nominal_s}s "
              f"battery={'wall' if d.wall_powered else f'{d.battery_wh}Wh'}")

    # traces longer than the horizon so fast devices never idle out —
    # their extra wakes are the point of event-driven stepping.
    # placement=True so the placement layer shows up in the trace too.
    recorder = TraceRecorder()
    ctl = FleetController(fleet, cfg, shape, trace_ticks=80,
                          warmup_ticks=4, placement=True,
                          recorder=recorder)

    # back the light-tier device with a real engine: measured step times
    # become its telemetry observations.  build_engine wires it to the
    # fleet's shared compile cache under the device's platform domain.
    engine_dev = next(d for d in fleet if d.tier == "light")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ctl.build_engine(engine_dev.device_id, params, cfg=cfg,
                              slots=2, max_seq=128, steps_per_tick=3)
    rng = np.random.default_rng(0)
    for i in range(12):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 16))).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=24))
    engine.step()      # warm up jit compiles so telemetry sees steady state
    ctl.set_sla(engine_dev.device_id, 5e-3)   # 5 ms/step, externally given
    print(f"\nengine-backed device: {engine_dev.device_id} "
          f"(real decode-step wall times feed telemetry + next-wake)")

    ctl.run_for(16.0)   # 16 simulated seconds of independent ticking

    rep = fleet_report(ctl)
    print("\n" + rep.render())
    print(f"\nper-device wakes over {ctl.now_s:.0f}s of fleet time "
          f"(clock skew {rep.clock_skew_s:.2f}s):")
    for did, n in sorted(rep.device_ticks.items(), key=lambda kv: -kv[1]):
        print(f"  {did:24s} {n:3d} ticks")

    print("\nlearned tier calibrations (observed/predicted), per channel:")
    from repro.fleet import CHANNELS
    for tier in ("heavy", "medium", "light"):
        for chan in CHANNELS:
            c = ctl.telemetry.calibration_for_tier(tier, chan)
            if not c.samples:
                continue
            print(f"  {tier:6s}/{chan:9s} latency ×{c.latency_scale:.2f} "
                  f"{c.latency_bias_s:+.2e}s  energy ×{c.energy_scale:.2f}  "
                  f"({c.samples} samples)")
    done = sum(1 for t in engine.step_times)
    print(f"\nengine: {engine.stats.steps} steps, "
          f"{engine.stats.tokens_out} tokens, "
          f"median step {sorted(engine.step_times)[done // 2]*1e3:.2f} ms, "
          f"ewma {engine.step_time_ewma_s*1e3:.2f} ms")

    # ---- one timeline for the whole cross-level loop ----------------
    path = write_trace(recorder, "trace.json")
    by_layer = {cat: sum(1 for e in recorder.events if e.cat == cat)
                for cat in LAYERS}
    print(f"\ntrace: {len(recorder.events)} events -> {path} "
          f"(open in https://ui.perfetto.dev)")
    for cat in LAYERS:
        print(f"  {cat:10s} {by_layer[cat]:5d} events")
    print("metrics snapshot (fleet registry):")
    for name, val in ctl.metrics.snapshot().items():
        print(f"  {name:28s} {val}")


if __name__ == "__main__":
    main()
