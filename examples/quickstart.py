"""Quickstart: register a model with the CrowdHMTware middleware and let
the cross-level adaptation loop pick the deployment strategy as the
context changes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Budgets, Middleware, ResourceContext
from repro.models import init_params
from repro.models.configs import InputShape


def main():
    cfg = get_config("paper-backbone")
    print(f"backbone: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # the paper's run.py(device_id, model, IP, PORT, fuse, quan) analogue
    mw = Middleware(cfg=cfg, params=params,
                    shape=InputShape("app", 256, 4, "prefill"),
                    budgets=Budgets(latency_s=0.05, memory_bytes=2e9),
                    fuse=True, quan=False)
    print(f"offline Pareto front: {len(mw.loop.front)} configurations")

    # three contexts: plugged in -> battery low -> memory pressure
    for name, ctx in [
        ("plugged-in", ResourceContext(battery_frac=0.95)),
        ("battery-low", ResourceContext(battery_frac=0.15)),
        ("mem-pressure", ResourceContext(battery_frac=0.5,
                                         mem_free_frac=0.2)),
    ]:
        d = mw.adapt(ctx)
        print(f"[{name:12s}] {d.reason:10s} -> {d.action.describe()}")
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                    cfg.vocab_size)
        logits = mw.infer(tokens)
        print(f"               inferred logits {logits.shape}, "
              f"A_est={d.eval.accuracy:.3f} "
              f"E_est={d.eval.energy_j:.2e}J "
              f"M_est={d.eval.memory_bytes/1e6:.0f}MB")
    print("\nadaptation log:")
    print(mw.report())


if __name__ == "__main__":
    main()
