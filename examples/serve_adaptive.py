"""Adaptive serving: batched requests through the serving engine while the
middleware swaps elastic variants as the day-long context trace evolves
(the paper's vehicle/drone case study, §IV-G).

  PYTHONPATH=src python examples/serve_adaptive.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
